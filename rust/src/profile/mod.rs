//! Event profiling (paper §4.2) on a **two-node slice** of the cluster.
//!
//! Each unique event is measured in isolation by running a minimal program
//! on the ground-truth engine — one rank for computation events, a
//! sender/receiver pair for point-to-point events (the dPRO min-rule falls
//! out of measuring the transfer span itself, excluding queuing), and an
//! up-to-8-rank / 2-node ring for all-reduce events, extrapolated to larger
//! groups with the 2(N-1)P/N law.
//!
//! The profiler never sees more than two nodes, mirroring the paper's
//! protocol, and it accounts every GPU-second it burns — the currency of
//! the paper's Table 3.

pub mod calibrate;

use crate::cluster::{ClusterSpec, LinkClass, Placement};
use crate::comm;
use crate::cost::CostBook;
use crate::engine::program::{Instr, Program};
use crate::engine::EngineParams;
use crate::events::{CommEvent, Event, EventDb, EventId};
use crate::schedule::Phase;
use crate::timeline::{SpanKind, Tag};
use crate::util::stats;

/// Cap on devices used to profile a single all-reduce (paper: 8 GPUs / 2
/// nodes, beyond which the ring law extrapolates).
pub const MAX_PROFILE_RING: usize = 8;

/// Accounting of what profiling cost (Table 3).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ProfileReport {
    /// Total GPU-time consumed: sum over events of devices x elapsed x iters.
    pub gpu_seconds: f64,
    /// Number of unique events profiled.
    pub events_profiled: usize,
    /// Events that needed ring-law extrapolation (group > cap).
    pub extrapolated: usize,
    /// Event lookups answered from a shared [`crate::search::ProfileCache`]
    /// instead of re-profiling (0 on uncached paths) — the measured form of
    /// the paper's Table-3 dedup saving.
    pub cache_hits: usize,
}

/// The measured cost of one event, as produced by [`profile_single`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProfiledEvent {
    /// Mean elapsed time over the profiling iterations, us.
    pub mean_us: f64,
    /// Devices the profiling micro-program occupied.
    pub devices: usize,
    /// Whether the ring law extrapolated beyond the 2-node slice.
    pub extrapolated: bool,
}

impl ProfiledEvent {
    /// GPU-seconds this measurement burned (Table-3 currency).
    pub fn gpu_seconds(&self, iters: usize) -> f64 {
        self.mean_us * 1e-6 * iters as f64 * self.devices as f64
    }
}

/// The profiling testbed: a 2-node slice of the target cluster, stripped
/// of heterogeneity — each micro-program runs on a *uniform* pair of nodes
/// of one SKU. Computation events are profiled on a slice of *their* kind
/// (see [`profile_single`]); communication events on the reference kind 0
/// (their cost is a property of the fabric, not the SKU).
fn profiling_slice(cluster: &ClusterSpec) -> ClusterSpec {
    let mut slice = cluster.clone();
    slice.nodes = cluster.nodes.min(2);
    slice.extra_kinds.clear();
    slice.kind_of_device.clear();
    slice.placement = Placement::Linear;
    slice
}

fn quiet_tag(kind: SpanKind) -> Tag {
    Tag {
        stage: 0,
        mb: 0,
        phase: Phase::Fwd,
        layer: 0,
        kind,
        idx: 0,
    }
}

/// Profile every unprofiled event in `db`, filling in mean elapsed times.
///
/// `iters` iterations are averaged per event (the paper uses 100); the
/// seed is independent of the ground truth's, so profiling sees *different*
/// jitter — the paper's "random fluctuation during profiling".
pub fn profile_events(
    db: &mut EventDb,
    cluster: &ClusterSpec,
    book: &CostBook,
    jitter_sigma: f64,
    iters: usize,
    seed: u64,
) -> ProfileReport {
    let mut report = ProfileReport::default();
    for id in db.unprofiled() {
        let p = profile_single(db, id, cluster, book, jitter_sigma, iters, seed);
        db.set_elapsed(id, p.mean_us);
        report.gpu_seconds += p.gpu_seconds(iters);
        report.events_profiled += 1;
        report.extrapolated += usize::from(p.extrapolated);
    }
    report
}

/// Profile one event in isolation on the 2-node slice.
///
/// The measurement depends only on the event *descriptor* (shape/bytes/
/// group/link), the cluster, the cost model and the (jitter, iters, seed)
/// protocol — never on which candidate interned it or in what order. That
/// independence is what lets [`crate::search::ProfileCache`] share results
/// across an entire strategy sweep while staying bit-deterministic.
pub fn profile_single(
    db: &EventDb,
    id: EventId,
    cluster: &ClusterSpec,
    book: &CostBook,
    jitter_sigma: f64,
    iters: usize,
    seed: u64,
) -> ProfiledEvent {
    let slice = profiling_slice(cluster);
    let event = db.get(id).clone();
    let (mean_us, devices, extrapolated) = match &event {
        Event::Comp(c) => {
            // measure on a slice of the event's own SKU: the descriptor's
            // device kind must resolve in the target cluster's kind table
            let spec = cluster.kind_by_name(&c.kind).unwrap_or_else(|| {
                panic!(
                    "comp event '{}' targets device kind '{}', unknown to this cluster",
                    c.name, c.kind
                )
            });
            let mut kind_slice = slice.clone();
            kind_slice.device = spec.clone();
            let t = profile_comp(id, db, &kind_slice, book, jitter_sigma, iters, seed);
            (t, 1, false)
        }
        Event::Comm(CommEvent::P2p { link, .. }) => {
            let t = profile_p2p(id, db, &slice, book, jitter_sigma, iters, seed, *link);
            (t, 2, false)
        }
        Event::Comm(CommEvent::AllReduce { group, link, bytes }) => {
            let profiled_n = (*group).min(ring_cap(&slice, *link));
            let t = profile_allreduce(
                id, db, &slice, book, jitter_sigma, iters, seed, *link, profiled_n,
            );
            let t = if profiled_n < *group {
                // §4.2 extrapolation beyond the 2-node slice: scale the
                // measurement by the ring-law ratio between the target
                // group (synthetic Megatron placement on the full
                // cluster) and the profiled group — the analytic
                // relation the paper derives from 2(N-1)P/N.
                let target = comm::synthetic_group(cluster, *group, *link);
                let prof_members = profile_members(&slice, *link, profiled_n);
                let law_target =
                    comm::hierarchical_allreduce_time_us(cluster, &target, *bytes);
                let law_prof =
                    comm::hierarchical_allreduce_time_us(&slice, &prof_members, *bytes);
                t * law_target / law_prof
            } else {
                t
            };
            (t, profiled_n, profiled_n < *group)
        }
    };
    ProfiledEvent {
        mean_us,
        devices,
        extrapolated,
    }
}

/// Where the profiler physically places an n-rank ring on the slice.
fn profile_members(slice: &ClusterSpec, link: LinkClass, n: usize) -> Vec<usize> {
    match link {
        LinkClass::Intra => (0..n).collect(),
        LinkClass::Inter => {
            let half = n.div_ceil(2);
            (0..n)
                .map(|i| if i < half { i } else { slice.gpus_per_node + (i - half) })
                .collect()
        }
    }
}

/// Largest ring the 2-node slice can host for a link class.
fn ring_cap(slice: &ClusterSpec, link: LinkClass) -> usize {
    match link {
        LinkClass::Intra => slice.gpus_per_node.min(MAX_PROFILE_RING),
        LinkClass::Inter => (2 * slice.gpus_per_node).min(MAX_PROFILE_RING),
    }
}

fn run_micro(
    prog: &Program,
    db: &EventDb,
    slice: &ClusterSpec,
    book: &CostBook,
    jitter_sigma: f64,
    iters: usize,
    seed: u64,
    device: usize,
    kind: SpanKind,
) -> f64 {
    // price the micro-program once; only jitter varies across iterations,
    // and one scratch serves all of them (the paper's protocol runs ~100
    // iterations per event — per-iteration engine allocation was pure
    // allocator churn across a sweep)
    let base = crate::engine::BaseCosts::compute(prog, db, slice, book);
    let mut scratch = crate::engine::ExecScratch::new();
    let samples: Vec<f64> = (0..iters)
        .map(|i| {
            let tl = crate::engine::execute_with_scratch(
                prog,
                db,
                slice,
                &base,
                &EngineParams {
                    jitter_sigma,
                    clock_skew_us: 0.0,
                    contention: false,
                    seed: seed ^ (0x9E37 + i as u64),
                },
                &mut scratch,
            );
            let dur = tl
                .device_spans(device)
                .iter()
                .find(|s| s.tag.kind == kind)
                .map(|s| s.dur())
                .expect("profiling program produced no span");
            scratch.recycle(tl);
            dur
        })
        .collect();
    stats::mean(&samples)
}

fn profile_comp(
    id: EventId,
    db: &EventDb,
    slice: &ClusterSpec,
    book: &CostBook,
    jitter_sigma: f64,
    iters: usize,
    seed: u64,
) -> f64 {
    let prog = Program {
        instrs: vec![vec![Instr::Comp {
            event: id,
            tag: quiet_tag(SpanKind::Comp),
        }]],
        groups: vec![],
    };
    run_micro(&prog, db, slice, book, jitter_sigma, iters, seed, 0, SpanKind::Comp)
}

#[allow(clippy::too_many_arguments)]
fn profile_p2p(
    id: EventId,
    db: &EventDb,
    slice: &ClusterSpec,
    book: &CostBook,
    jitter_sigma: f64,
    iters: usize,
    seed: u64,
    link: LinkClass,
) -> f64 {
    // place the pair on one node (intra) or across the two nodes (inter)
    let receiver = match link {
        LinkClass::Intra => 1,
        LinkClass::Inter => slice.gpus_per_node,
    };
    let mut instrs = vec![Vec::new(); receiver + 1];
    instrs[0] = vec![Instr::Send {
        peer: receiver,
        event: id,
        tag: quiet_tag(SpanKind::P2p),
    }];
    instrs[receiver] = vec![Instr::Recv {
        peer: 0,
        event: id,
        tag: quiet_tag(SpanKind::P2p),
    }];
    let prog = Program {
        instrs,
        groups: vec![],
    };
    run_micro(
        &prog, db, slice, book, jitter_sigma, iters, seed, receiver, SpanKind::P2p,
    )
}

#[allow(clippy::too_many_arguments)]
fn profile_allreduce(
    id: EventId,
    db: &EventDb,
    slice: &ClusterSpec,
    book: &CostBook,
    jitter_sigma: f64,
    iters: usize,
    seed: u64,
    link: LinkClass,
    n: usize,
) -> f64 {
    // membership: pack one node for intra, straddle both nodes for inter
    let members = profile_members(slice, link, n);
    let world = members.iter().max().unwrap() + 1;
    let mut instrs = vec![Vec::new(); world];
    for &m in &members {
        instrs[m] = vec![Instr::AllReduce {
            group: 0,
            event: id,
            tag: quiet_tag(SpanKind::MpAllReduce),
        }];
    }
    let prog = Program {
        instrs,
        groups: vec![members.clone()],
    };
    run_micro(
        &prog, db, slice, book, jitter_sigma, iters, seed, members[0], SpanKind::MpAllReduce,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{CostModel, OpClass};
    use crate::events::CompEvent;

    fn db_with(ev: Event) -> (EventDb, EventId) {
        let mut db = EventDb::new();
        let id = db.intern(ev);
        (db, id)
    }

    fn cluster() -> ClusterSpec {
        ClusterSpec::a40_cluster(4, 4)
    }

    #[test]
    fn comp_profile_matches_cost_model_when_quiet() {
        let (mut db, id) = db_with(Event::Comp(CompEvent {
            name: "x".into(),
            class: OpClass::Matmul,
            flops: 1 << 30,
            bytes: 1 << 24,
            kind: "A40".into(),
        }));
        let c = cluster();
        let cost = CostModel::default();
        profile_events(&mut db, &c, &CostBook::default(), 0.0, 3, 7);
        let want = cost.op_latency_us(&c.device, OpClass::Matmul, 1 << 30, 1 << 24);
        assert!((db.elapsed(id) / want - 1.0).abs() < 1e-9);
    }

    #[test]
    fn profiled_mean_approaches_base_under_jitter() {
        let (mut db, id) = db_with(Event::Comp(CompEvent {
            name: "x".into(),
            class: OpClass::Matmul,
            flops: 1 << 30,
            bytes: 1 << 24,
            kind: "A40".into(),
        }));
        let c = cluster();
        let cost = CostModel::default();
        profile_events(&mut db, &c, &CostBook::default(), 0.03, 200, 11);
        let want = cost.op_latency_us(&c.device, OpClass::Matmul, 1 << 30, 1 << 24);
        assert!(
            (db.elapsed(id) / want - 1.0).abs() < 0.01,
            "mean {} vs base {}",
            db.elapsed(id),
            want
        );
    }

    #[test]
    fn p2p_profile_matches_law() {
        for link in [LinkClass::Intra, LinkClass::Inter] {
            let (mut db, id) = db_with(Event::Comm(CommEvent::P2p {
                bytes: 1 << 22,
                link,
            }));
            let c = cluster();
            profile_events(&mut db, &c, &CostBook::default(), 0.0, 3, 7);
            let want = comm::p2p_time_us(&c, link, 1 << 22);
            assert!(
                (db.elapsed(id) / want - 1.0).abs() < 1e-9,
                "{link:?}"
            );
        }
    }

    #[test]
    fn small_allreduce_profiled_directly() {
        let (mut db, id) = db_with(Event::Comm(CommEvent::AllReduce {
            bytes: 1 << 24,
            group: 4,
            link: LinkClass::Intra,
        }));
        let c = cluster();
        let rep = profile_events(&mut db, &c, &CostBook::default(), 0.0, 3, 7);
        assert_eq!(rep.extrapolated, 0);
        let want = comm::allreduce_time_us(&c, LinkClass::Intra, 4, 1 << 24);
        assert!((db.elapsed(id) / want - 1.0).abs() < 1e-9);
    }

    #[test]
    fn large_allreduce_is_extrapolated_within_2pct() {
        // the paper's §4.2 claim: extrapolation beyond 8 GPUs changes
        // iteration-time prediction by < 2%
        let (mut db, id) = db_with(Event::Comm(CommEvent::AllReduce {
            bytes: 1 << 26,
            group: 16,
            link: LinkClass::Inter,
        }));
        let c = cluster();
        let rep = profile_events(&mut db, &c, &CostBook::default(), 0.0, 3, 7);
        assert_eq!(rep.extrapolated, 1);
        // ground truth: 16 ranks over 4 nodes, hierarchical
        let members: Vec<usize> = (0..16).collect();
        let want = comm::hierarchical_allreduce_time_us(&c, &members, 1 << 26);
        let got = db.elapsed(id);
        let err = (got - want).abs() / want;
        assert!(err < 0.02, "extrapolation err {err} (got {got}, want {want})");
    }

    fn mixed_comp(kind: &str) -> Event {
        Event::Comp(CompEvent {
            name: "x".into(),
            class: OpClass::Matmul,
            flops: 1 << 30,
            bytes: 1 << 24,
            kind: kind.into(),
        })
    }

    #[test]
    fn comp_profile_prices_on_the_events_own_kind() {
        // the same shapes, stamped A40 vs A10, measure to different costs
        let c = ClusterSpec::mixed_a40_a10(4, 4);
        let (mut db, fast) = db_with(mixed_comp("A40"));
        let slow = db.intern(mixed_comp("A10"));
        profile_events(&mut db, &c, &CostBook::default(), 0.0, 2, 7);
        let cost = CostModel::default();
        let want_fast = cost.op_latency_us(
            &crate::cluster::DeviceSpec::a40(),
            OpClass::Matmul,
            1 << 30,
            1 << 24,
        );
        let want_slow = cost.op_latency_us(
            &crate::cluster::DeviceSpec::a10(),
            OpClass::Matmul,
            1 << 30,
            1 << 24,
        );
        assert!((db.elapsed(fast) / want_fast - 1.0).abs() < 1e-9);
        assert!((db.elapsed(slow) / want_slow - 1.0).abs() < 1e-9);
        assert!(db.elapsed(slow) > db.elapsed(fast));
    }

    #[test]
    fn per_kind_cost_override_applies_to_that_kind_only() {
        let c = ClusterSpec::mixed_a40_a10(4, 4);
        let mut slow_model = CostModel::default();
        slow_model.scale = 2.0;
        let book = CostBook::default().with_kind("A10", slow_model);
        let (mut db, fast) = db_with(mixed_comp("A40"));
        let slow = db.intern(mixed_comp("A10"));
        profile_events(&mut db, &c, &book, 0.0, 2, 7);
        let mut plain_db = EventDb::new();
        let pf = plain_db.intern(mixed_comp("A40"));
        let ps = plain_db.intern(mixed_comp("A10"));
        profile_events(&mut plain_db, &c, &CostBook::default(), 0.0, 2, 7);
        assert_eq!(db.elapsed(fast), plain_db.elapsed(pf), "A40 unaffected");
        assert!(
            (db.elapsed(slow) / plain_db.elapsed(ps) - 2.0).abs() < 1e-9,
            "A10 override must scale only A10 events"
        );
    }

    #[test]
    #[should_panic(expected = "unknown to this cluster")]
    fn comp_profile_rejects_unknown_kind() {
        let (mut db, _) = db_with(mixed_comp("H100"));
        profile_events(&mut db, &cluster(), &CostBook::default(), 0.0, 1, 7);
    }

    #[test]
    fn gpu_seconds_accounted() {
        let (mut db, _) = db_with(Event::Comp(CompEvent {
            name: "x".into(),
            class: OpClass::Matmul,
            flops: 1 << 32,
            bytes: 1 << 24,
            kind: "A40".into(),
        }));
        let rep = profile_events(&mut db, &cluster(), &CostBook::default(), 0.0, 10, 7);
        assert!(rep.gpu_seconds > 0.0);
        assert_eq!(rep.events_profiled, 1);
    }
}
