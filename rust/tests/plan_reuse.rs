//! Compiled sweep plans (ISSUE 10): byte-identity and delta-aware reuse.
//!
//! The contract under test: a [`SweepPlan`] changes what a sweep *costs*,
//! never what it *returns*. Every test here serializes reports through
//! the same `protocol::sweep_response` path the daemon writes, so
//! "identical" means identical response bytes, not just equal floats.

use std::io::Cursor;
use std::sync::Arc;

use distsim::cluster::ClusterSpec;
use distsim::config::Json;
use distsim::cost::CostBook;
use distsim::model::{zoo, ModelSpec};
use distsim::search::{ProfileCache, SearchEngine, SweepConfig, SweepPlan, SweepReport};
use distsim::service::{protocol, serve_ndjson, ServeOpts};

fn model() -> ModelSpec {
    zoo::bert_large()
}

fn cfg() -> SweepConfig {
    SweepConfig {
        global_batch: 8,
        profile_iters: 1,
        prune: true,
        ..SweepConfig::default()
    }
}

/// A fresh engine over its own cache — the cold path the plan must match.
fn engine<'a>(
    model: &'a ModelSpec,
    cluster: &'a ClusterSpec,
    book: &CostBook,
    cfg: &SweepConfig,
) -> SearchEngine<'a> {
    SearchEngine::with_book(
        model,
        cluster,
        book.clone(),
        cfg.clone(),
        Arc::new(ProfileCache::new()),
    )
}

/// Serialize a report exactly as the daemon's writer would (fixed id and
/// fingerprint, engine-side cache stats, no timing, no trace).
fn serialize(report: &SweepReport) -> String {
    protocol::sweep_response(Some("x"), "fp", report, &report.cache, false, None).to_string()
}

#[test]
fn planned_sweep_is_byte_identical_to_cold_and_relaunch_is_a_full_hit() {
    let model = model();
    let cluster = ClusterSpec::a40_cluster(1, 4);
    let book = CostBook::default();
    let cfg = cfg();

    // two cold sweeps pin the baseline's own determinism first
    let cold_a = serialize(&engine(&model, &cluster, &book, &cfg).sweep());
    let cold_b = serialize(&engine(&model, &cluster, &book, &cfg).sweep());
    assert_eq!(cold_a, cold_b, "cold sweeps must agree with themselves");

    // compile once, launch twice: both planned sweeps match the cold bytes
    let plan = Arc::new(SweepPlan::compile(&model, &cluster, &book, &cfg));
    let warm_1 = serialize(
        &engine(&model, &cluster, &book, &cfg)
            .with_plan(plan.clone())
            .sweep(),
    );
    assert_eq!(cold_a, warm_1, "planned sweep diverged from cold bytes");

    let (relaunched, reuse) = plan.launch(&model, &cluster, &book, &cfg, None);
    assert!(reuse.full_hit(), "identical request must be a 100% hit: {reuse:?}");
    let warm_2 = serialize(
        &engine(&model, &cluster, &book, &cfg)
            .with_plan(Arc::new(relaunched))
            .sweep(),
    );
    assert_eq!(cold_a, warm_2, "relaunched plan diverged from cold bytes");
}

/// The delta matrix at sweep level: each single-input delta keeps every
/// untouched component and the delta'd sweep still matches its own cold
/// baseline byte for byte.
#[test]
fn delta_launches_stay_byte_identical_to_their_cold_baselines() {
    let model = model();
    let cluster = ClusterSpec::a40_cluster(1, 4);
    let book = CostBook::default();
    let cfg = cfg();
    let plan = SweepPlan::compile(&model, &cluster, &book, &cfg);

    // capacity delta: memory stage re-runs, space/bounds/events reused
    let capped = cluster.with_uniform_capacity(2_000_000_000);
    let (for_capped, reuse) = plan.launch(&model, &capped, &book, &cfg, None);
    assert!(
        reuse.space && reuse.bounds && reuse.events && !reuse.memory,
        "capacity delta reuse: {reuse:?}"
    );
    let cold = serialize(&engine(&model, &capped, &book, &cfg).sweep());
    let warm = serialize(
        &engine(&model, &capped, &book, &cfg)
            .with_plan(Arc::new(for_capped))
            .sweep(),
    );
    assert_eq!(cold, warm, "capacity-delta planned sweep diverged");

    // cost-book delta: bounds re-price, everything else reused
    let mut edited = CostBook::default();
    edited.base.eff_max *= 0.9;
    let (for_edited, reuse) = plan.launch(&model, &cluster, &edited, &cfg, None);
    assert!(
        reuse.space && reuse.memory && reuse.events && !reuse.bounds,
        "cost-book delta reuse: {reuse:?}"
    );
    let cold = serialize(&engine(&model, &cluster, &edited, &cfg).sweep());
    let warm = serialize(
        &engine(&model, &cluster, &edited, &cfg)
            .with_plan(Arc::new(for_edited))
            .sweep(),
    );
    assert_eq!(cold, warm, "cost-book-delta planned sweep diverged");

    // shape delta (batch axis): nothing survives, and the fresh plan's
    // sweep still matches its cold baseline
    let mut bigger = cfg.clone();
    bigger.global_batch = 16;
    let reuse = plan.reuse_against(&model, &cluster, &book, &bigger);
    assert!(!reuse.any(), "shape delta must invalidate everything: {reuse:?}");
    let (fresh, _) = plan.launch(&model, &cluster, &book, &bigger, None);
    let cold = serialize(&engine(&model, &cluster, &book, &bigger).sweep());
    let warm = serialize(
        &engine(&model, &cluster, &book, &bigger)
            .with_plan(Arc::new(fresh))
            .sweep(),
    );
    assert_eq!(cold, warm, "recompiled planned sweep diverged");
}

// ---------------------------------------------------------------------------
// daemon end to end

fn run_lines(input: &str, workers: usize) -> Vec<String> {
    let mut out: Vec<u8> = Vec::new();
    serve_ndjson(
        Cursor::new(input.to_string()),
        &mut out,
        &ServeOpts {
            workers,
            ..ServeOpts::default()
        },
    );
    String::from_utf8(out)
        .expect("responses are utf-8")
        .lines()
        .map(str::to_string)
        .collect()
}

fn sweep_line(id: &str, global_batch: usize) -> String {
    format!(
        r#"{{"id":"{id}","op":"sweep","model":"bert-large","cluster":{{"preset":"a40","nodes":1,"gpus_per_node":4}},"sweep":{{"global_batch":{global_batch},"profile_iters":1,"prune":true}}}}"#
    )
}

/// Sweep responses through the always-on daemon plan cache are
/// bit-identical for any worker count — including repeated shapes, where
/// later requests ride the compiled plan.
#[test]
fn daemon_plan_cache_keeps_responses_bit_identical_for_any_worker_count() {
    let input = [
        sweep_line("a", 8),
        sweep_line("b", 16),
        sweep_line("a-again", 8),
        sweep_line("a-thrice", 8),
    ]
    .join("\n");
    let serial = run_lines(&input, 1);
    assert_eq!(serial.len(), 4);
    for workers in [2, 4] {
        assert_eq!(
            serial,
            run_lines(&input, workers),
            "{workers} workers diverged from serial with the plan cache on"
        );
    }
}

/// With one worker the accounting is exact: the repeat of a shape is a
/// full plan hit, a scenario-salted repeat is a partial reuse, and
/// `compiles + hits + partial` equals the plan-cached sweeps served.
#[test]
fn stats_reports_plan_hits_and_the_accounting_reconciles() {
    let salted = r#"{"id":"c","op":"sweep","model":"bert-large","cluster":{"preset":"a40","nodes":1,"gpus_per_node":4},"sweep":{"global_batch":8,"profile_iters":1,"prune":true,"scenario":{"stragglers":[{"device":0,"factor":1.5}]}}}"#;
    let input = [
        sweep_line("cold", 8),
        sweep_line("warm", 8),
        salted.to_string(),
        r#"{"id":"s","op":"stats"}"#.to_string(),
    ]
    .join("\n");
    let lines = run_lines(&input, 1);
    assert_eq!(lines.len(), 4);

    // identical requests answer with identical bytes modulo the id
    let strip_id = |line: &str, id: &str| line.replace(&format!(r#""id":"{id}""#), r#""id":_"#);
    assert_eq!(
        strip_id(&lines[0], "cold"),
        strip_id(&lines[1], "warm"),
        "plan-hit response diverged from the compile response"
    );

    let stats = Json::parse(&lines[3]).expect("stats line parses");
    let plans = stats
        .get("result")
        .and_then(|r| r.get("plans"))
        .unwrap_or_else(|| panic!("no result.plans in {stats}"));
    let field = |k: &str| {
        plans
            .get(k)
            .and_then(Json::as_usize)
            .unwrap_or_else(|| panic!("no plans.{k} in {stats}"))
    };
    let (compiles, hits, partial) = (field("compiles"), field("hits"), field("partial"));
    assert_eq!(compiles, 1, "one shape, one cold compile");
    assert_eq!(hits, 1, "the identical repeat is a full hit");
    assert_eq!(partial, 1, "the scenario-salted repeat is a partial reuse");
    assert_eq!(compiles + hits + partial, 3, "every sweep lands in one bucket");
}
