//! Integration tests for the unhappy-path scenario engine (ISSUE 7):
//! empty-scenario bit-identity, thread/worker-count determinism of
//! scenario-scored sweeps, straggler monotonicity, single-counted restart
//! accounting, and the elastic-resize strategy flip.

use std::io::Cursor;
use std::sync::Arc;

use distsim::cluster::ClusterSpec;
use distsim::config::RunConfig;
use distsim::cost::CostModel;
use distsim::engine::GroundTruth;
use distsim::scenario::{Failure, Resize, ScenarioSpec, Straggler};
use distsim::search::{SearchEngine, SweepConfig, SweepReport};
use distsim::service::{serve_ndjson, ServeOpts};
use distsim::strategy::Strategy;
use distsim::timeline::Timeline;

fn small_run_cfg() -> RunConfig {
    let mut cfg = RunConfig::new(
        "bert-large",
        Strategy::new(1, 2, 2),
        ClusterSpec::a40_cluster(1, 4),
    );
    cfg.micro_batches = 2;
    cfg.micro_batch_size = 2;
    cfg
}

/// Every span's placement and exact time bits — bit-level equality.
fn span_bits(t: &Timeline) -> Vec<(usize, u64, u64)> {
    t.spans()
        .iter()
        .map(|s| (s.device, s.start.to_bits(), s.end.to_bits()))
        .collect()
}

fn straggler_spec(device: usize, factor: f64) -> ScenarioSpec {
    ScenarioSpec {
        stragglers: vec![Straggler { device, factor }],
        ..ScenarioSpec::default()
    }
}

fn sweep_with(cluster: &ClusterSpec, scenario: ScenarioSpec, threads: usize) -> SweepReport {
    let model = distsim::model::zoo::bert_large();
    let cost = CostModel::default();
    let cfg = SweepConfig {
        global_batch: 8,
        profile_iters: 1,
        threads,
        scenario,
        ..SweepConfig::default()
    };
    SearchEngine::new(&model, cluster, &cost, cfg).sweep()
}

#[test]
fn empty_scenario_is_bit_identical_through_the_public_api() {
    let cfg = small_run_cfg();
    let plain = GroundTruth::prepare(&cfg).expect("prepare");
    let scoped = GroundTruth::prepare(&cfg)
        .expect("prepare")
        .with_scenario(Arc::new(ScenarioSpec::default()));
    for iter in 0..3 {
        let a = plain.run_iteration(iter);
        let b = scoped.run_iteration(iter);
        assert_eq!(
            span_bits(&a),
            span_bits(&b),
            "iteration {iter}: empty scenario must not move a single span"
        );
    }
}

#[test]
fn scenario_sweep_responses_are_byte_identical_across_worker_counts() {
    // straggler + failure: exercises both the degraded walk and the
    // restart accounting through the full daemon path
    let req = r#"{"id":"scn","op":"sweep","model":"bert-large","cluster":{"preset":"a40","nodes":1,"gpus_per_node":4},"sweep":{"global_batch":4,"profile_iters":1,"scenario":{"failures":[{"device":1,"at_us":2500,"checkpoint_interval_us":1000,"restart_us":300}],"stragglers":[{"device":0,"factor":1.5}]}}}"#;
    let serve = |workers: usize| -> Vec<u8> {
        let mut out = Vec::new();
        let opts = ServeOpts {
            workers,
            cache_dir: None,
            ..ServeOpts::default()
        };
        serve_ndjson(Cursor::new(format!("{req}\n{req}\n")), &mut out, &opts);
        out
    };
    let one = serve(1);
    let text = String::from_utf8(one.clone()).expect("utf-8 responses");
    assert!(text.contains("\"robustness\""), "no robustness block: {text}");
    assert!(
        text.contains("\"scenario_throughput\""),
        "no per-candidate scenario throughput: {text}"
    );
    for workers in [2, 4] {
        assert_eq!(
            one,
            serve(workers),
            "scenario sweep responses must be byte-identical at {workers} workers"
        );
    }
}

#[test]
fn scenario_sweep_reports_are_identical_across_thread_counts() {
    let cluster = ClusterSpec::a40_cluster(1, 4);
    let spec = straggler_spec(0, 2.0);
    let r1 = sweep_with(&cluster, spec.clone(), 1);
    let r4 = sweep_with(&cluster, spec, 4);
    assert_eq!(r1.candidates.len(), r4.candidates.len());
    for (a, b) in r1.candidates.iter().zip(&r4.candidates) {
        assert_eq!(a.strategy, b.strategy);
        assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
        assert_eq!(
            a.scenario_throughput.to_bits(),
            b.scenario_throughput.to_bits(),
            "{}: scenario score differs across thread counts",
            a.strategy.notation()
        );
    }
    assert_eq!(r1.robustness, r4.robustness);
    assert!(r1.robustness.is_some());
}

#[test]
fn straggler_scores_degrade_monotonically_with_the_factor() {
    let cluster = ClusterSpec::a40_cluster(1, 4);
    // a factor-1.0 straggler is a non-empty spec with an identity degrade:
    // the scenario score must equal the nominal score
    let baseline = sweep_with(&cluster, straggler_spec(0, 1.0), 1);
    for c in baseline.candidates.iter().filter(|c| c.throughput > 0.0) {
        assert!(
            (c.scenario_throughput - c.throughput).abs() < 1e-9,
            "{}: identity straggler changed the score",
            c.strategy.notation()
        );
    }
    // the analytical degraded walk is a composition of sums and maxes of
    // durations, so a larger factor can never score higher
    let mut prev = baseline;
    for factor in [1.5, 2.0, 4.0] {
        let next = sweep_with(&cluster, straggler_spec(0, factor), 1);
        for (a, b) in prev.candidates.iter().zip(&next.candidates) {
            assert_eq!(a.strategy, b.strategy);
            if a.scenario_throughput > 0.0 {
                assert!(
                    b.scenario_throughput <= a.scenario_throughput + 1e-9,
                    "{} sped up when the straggler worsened to x{factor}",
                    a.strategy.notation()
                );
            }
        }
        prev = next;
    }

    // and the discrete-event ground truth agrees on the direction
    let cfg = small_run_cfg();
    let nominal = GroundTruth::prepare(&cfg).expect("prepare").run_iteration(0);
    let slowed = GroundTruth::prepare(&cfg)
        .expect("prepare")
        .with_scenario(Arc::new(straggler_spec(0, 4.0)))
        .run_iteration(0);
    assert!(
        slowed.batch_time_us() > nominal.batch_time_us(),
        "a 4x straggler must stretch the simulated batch"
    );
}

#[test]
fn restart_penalty_is_accounted_exactly_once() {
    let spec = ScenarioSpec {
        failures: vec![Failure {
            device: 1,
            at_us: 2500.0,
            checkpoint_interval_us: 1000.0,
            restart_us: 300.0,
        }],
        ..ScenarioSpec::default()
    };
    // 500 us of lost work since the last checkpoint + 300 us restart
    assert!((spec.restart_penalty_us() - 800.0).abs() < 1e-12);

    // a failure-only scenario leaves the walk untouched: every candidate's
    // scenario batch time is its nominal batch time plus the penalty, once
    let cluster = ClusterSpec::a40_cluster(1, 4);
    let report = sweep_with(&cluster, spec, 1);
    let mut checked = 0;
    for c in &report.candidates {
        if c.throughput > 0.0 && c.scenario_throughput > 0.0 {
            let nominal_us = 1e6 / c.throughput;
            let scenario_us = 1e6 / c.scenario_throughput;
            assert!(
                (scenario_us - nominal_us - 800.0).abs() < 1e-3,
                "{}: expected nominal + 800us, got {} vs {}",
                c.strategy.notation(),
                scenario_us,
                nominal_us
            );
            checked += 1;
        }
    }
    assert!(checked > 0, "no candidate was scenario-scored");
    let rb = report.robustness.expect("robustness block");
    assert!((rb.restart_penalty_us - 800.0).abs() < 1e-12);
    assert_eq!(rb.episodes, 1);
}

#[test]
fn elastic_resize_flips_the_winner() {
    // 2 nodes x 1 GPU with a pathological spine: grid(2) is exactly
    // {2M1P1D, 1M2P1D, 1M1P2D}, and the near-dead inter link makes the
    // 1.3 GB gradient allreduce of 1M1P2D hopeless, so the nominal winner
    // splits the model (dp = 1). Dropping one replica (dp_delta -1) then
    // makes every dp = 1 candidate unreachable — the robust choice must
    // flip to the data-parallel candidate that can survive the resize.
    let mut cluster = ClusterSpec::a40_cluster(2, 1);
    cluster.inter_bw_gbs = 0.02;
    let spec = ScenarioSpec {
        resize: Some(Resize {
            dp_delta: -1,
            reshard_us: 1000.0,
        }),
        ..ScenarioSpec::default()
    };
    let report = sweep_with(&cluster, spec, 1);
    assert_eq!(report.candidates.len(), 3, "grid(2) has 3 strategies");
    for c in &report.candidates {
        if c.strategy.dp == 1 {
            assert_eq!(
                c.scenario_throughput, 0.0,
                "{}: dp 1 cannot survive dp_delta -1",
                c.strategy.notation()
            );
        } else {
            assert!(
                c.scenario_throughput > 0.0,
                "{}: dp 2 must survive the resize",
                c.strategy.notation()
            );
        }
    }
    let rb = report.robustness.expect("robustness block");
    let nominal = &report.candidates[rb.nominal_best];
    let robust = &report.candidates[rb.scenario_best];
    assert_eq!(
        nominal.strategy.dp, 1,
        "over a 0.02 GB/s spine the nominal winner must avoid data \
         parallelism, got {}",
        nominal.strategy.notation()
    );
    assert_eq!(robust.strategy.dp, 2, "the robust winner must keep a replica to drop");
    assert_ne!(
        rb.nominal_best, rb.scenario_best,
        "the resize what-if must flip the recommendation"
    );
    // the nominal winner scores zero under the scenario, so deploying it
    // forfeits everything: regret is total
    assert!((rb.regret - 1.0).abs() < 1e-12, "regret {} should be 1", rb.regret);
}
