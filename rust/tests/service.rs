//! Integration tests for the what-if sweep service: protocol round-trips,
//! worker-count determinism, as-if-serial cache accounting, and
//! cross-restart snapshot persistence.

use std::io::Cursor;
use std::path::PathBuf;

use distsim::config::Json;
use distsim::service::{serve_ndjson, serve_tcp, ServeOpts, ServeSummary};

/// Run an NDJSON session in-process and return its response lines.
fn run_lines(input: &str, opts: &ServeOpts) -> (Vec<String>, ServeSummary) {
    let mut out: Vec<u8> = Vec::new();
    let summary = serve_ndjson(Cursor::new(input.to_string()), &mut out, opts);
    let text = String::from_utf8(out).expect("responses are utf-8");
    (text.lines().map(str::to_string).collect(), summary)
}

fn opts_with_workers(workers: usize) -> ServeOpts {
    ServeOpts {
        workers,
        cache_dir: None,
        ..ServeOpts::default()
    }
}

/// A small, fast sweep request: 6 candidates on 4 devices.
fn small_sweep(id: &str, global_batch: usize) -> String {
    format!(
        r#"{{"id":"{id}","op":"sweep","model":"bert-large","cluster":{{"preset":"a40","nodes":1,"gpus_per_node":4}},"sweep":{{"global_batch":{global_batch},"profile_iters":1}}}}"#
    )
}

fn parse(line: &str) -> Json {
    Json::parse(line).unwrap_or_else(|e| panic!("unparseable response '{line}': {e}"))
}

fn result_field<'a>(j: &'a Json, k: &str) -> &'a Json {
    j.get("result")
        .unwrap_or_else(|| panic!("no result in {j}"))
        .get(k)
        .unwrap_or_else(|| panic!("no result.{k} in {j}"))
}

#[test]
fn protocol_round_trip_good_bad_and_control_lines() {
    let input = [
        r#"{"id":"p1","op":"ping"}"#,
        "{definitely not json",
        r#"{"id":"q","op":"frobnicate"}"#,
        r#"{"id":"m","op":"sweep","model":"no-such-model","cluster":{"preset":"a40"}}"#,
        r#"{"op":"stats"}"#,
    ]
    .join("\n");
    let (lines, summary) = run_lines(&input, &opts_with_workers(2));
    assert_eq!(lines.len(), 5, "one response per line, in order: {lines:?}");
    assert_eq!(summary.requests, 5);
    assert_eq!(summary.errors, 3);

    let pong = parse(&lines[0]);
    assert_eq!(pong.get("id").and_then(Json::as_str), Some("p1"));
    assert_eq!(pong.get("ok").and_then(Json::as_bool), Some(true));

    let bad_json = parse(&lines[1]);
    assert_eq!(bad_json.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(bad_json.get("id"), Some(&Json::Null));
    assert_eq!(
        bad_json.get("error").unwrap().get("kind").and_then(Json::as_str),
        Some("bad_json")
    );

    let bad_op = parse(&lines[2]);
    assert_eq!(bad_op.get("id").and_then(Json::as_str), Some("q"));
    assert_eq!(
        bad_op.get("error").unwrap().get("kind").and_then(Json::as_str),
        Some("bad_request")
    );

    let bad_model = parse(&lines[3]);
    assert!(bad_model
        .get("error")
        .unwrap()
        .get("message")
        .and_then(Json::as_str)
        .unwrap()
        .contains("no-such-model"));

    let stats = parse(&lines[4]);
    assert_eq!(stats.get("ok").and_then(Json::as_bool), Some(true));
}

#[test]
fn sweep_response_carries_candidates_and_best() {
    let (lines, summary) = run_lines(&small_sweep("s1", 4), &opts_with_workers(1));
    assert_eq!((lines.len(), summary.sweeps), (1, 1));
    let j = parse(&lines[0]);
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
    let cands = result_field(&j, "candidates").as_arr().unwrap();
    assert_eq!(cands.len(), 6, "grid(4) has 6 strategies");
    for c in cands {
        assert!(c.get("strategy").and_then(Json::as_str).is_some());
        assert_eq!(c.get("schedule").and_then(Json::as_str), Some("dapple"));
    }
    assert!(result_field(&j, "best").get("throughput").and_then(Json::as_f64).unwrap() > 0.0);
    let speedup = result_field(&j, "speedup").as_f64().unwrap();
    assert!(speedup >= 1.0);
    // deterministic by default: no wall-clock in the response
    assert!(j.get("result").unwrap().get("timing").is_none());
}

#[test]
fn responses_are_bit_identical_for_any_worker_count() {
    // mixed session: two distinct sweeps, one repeat, an error line and a
    // ping interleaved — the response stream must not depend on how many
    // workers race on it
    let input = [
        small_sweep("a", 4),
        r#"{"op":"ping","id":"mid"}"#.to_string(),
        small_sweep("b", 8),
        "not json at all".to_string(),
        small_sweep("a-again", 4),
    ]
    .join("\n");
    let (one, s1) = run_lines(&input, &opts_with_workers(1));
    for workers in [2, 4] {
        let (many, sn) = run_lines(&input, &opts_with_workers(workers));
        assert_eq!(one, many, "{workers} workers diverged from serial");
        assert_eq!(s1, sn);
    }
}

#[test]
fn second_identical_request_is_a_full_cache_hit() {
    let input = format!("{}\n{}", small_sweep("cold", 4), small_sweep("warm", 4));
    let (lines, _) = run_lines(&input, &opts_with_workers(4));
    let cold = parse(&lines[0]);
    let warm = parse(&lines[1]);

    let cold_cache = result_field(&cold, "cache");
    assert!(cold_cache.get("misses").and_then(Json::as_usize).unwrap() > 0);
    assert!(cold_cache.get("gpu_seconds").and_then(Json::as_f64).unwrap() > 0.0);

    let warm_cache = result_field(&warm, "cache");
    assert_eq!(warm_cache.get("misses").and_then(Json::as_usize), Some(0));
    assert_eq!(warm_cache.get("gpu_seconds").and_then(Json::as_f64), Some(0.0));
    assert_eq!(warm_cache.get("hit_rate").and_then(Json::as_f64), Some(1.0));
    assert!(warm_cache.get("hits").and_then(Json::as_usize).unwrap() > 0);

    // and the shared cache must never change the answer
    assert_eq!(
        result_field(&cold, "candidates").to_string(),
        result_field(&warm, "candidates").to_string()
    );
}

fn fresh_cache_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "distsim_service_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn snapshots_survive_a_daemon_restart() {
    let dir = fresh_cache_dir("persist");
    let opts = ServeOpts {
        workers: 2,
        cache_dir: Some(dir.clone()),
        ..ServeOpts::default()
    };

    // session 1: cold sweep, then clean shutdown -> snapshot on disk
    let input = format!("{}\n{}", small_sweep("r", 4), r#"{"op":"shutdown"}"#);
    let (lines1, summary1) = run_lines(&input, &opts);
    assert_eq!(lines1.len(), 2);
    assert_eq!(summary1.snapshots_saved, 1);
    let first = parse(&lines1[0]);
    assert!(result_field(&first, "cache").get("misses").and_then(Json::as_usize).unwrap() > 0);
    let snapshot_files: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    assert_eq!(snapshot_files.len(), 1);
    assert!(snapshot_files[0].starts_with("cache-") && snapshot_files[0].ends_with(".json"));

    // session 2 (a "restarted daemon"): the same request is answered from
    // the loaded snapshot — identical payload, zero profiling cost
    let (lines2, _) = run_lines(&small_sweep("r", 4), &opts);
    let second = parse(&lines2[0]);
    assert_eq!(
        result_field(&first, "candidates").to_string(),
        result_field(&second, "candidates").to_string(),
        "restart with a persisted cache must not change the answer"
    );
    assert_eq!(
        result_field(&first, "fingerprint").as_str(),
        result_field(&second, "fingerprint").as_str()
    );
    let cache2 = result_field(&second, "cache");
    assert_eq!(cache2.get("misses").and_then(Json::as_usize), Some(0));
    assert_eq!(cache2.get("hit_rate").and_then(Json::as_f64), Some(1.0));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn budget_caps_candidates_and_deadlines_do_not_fire_when_generous() {
    let line = r#"{"id":"b","model":"bert-large","cluster":{"preset":"a40","nodes":1,"gpus_per_node":4},"sweep":{"global_batch":4,"profile_iters":1},"budget":{"max_candidates":3,"deadline_ms":600000}}"#;
    let (lines, _) = run_lines(line, &opts_with_workers(1));
    let j = parse(&lines[0]);
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(
        result_field(&j, "candidates").as_arr().unwrap().len(),
        3,
        "budget.max_candidates must truncate the space"
    );
}

#[test]
fn schedule_axis_attributes_wins_in_the_response() {
    let line = r#"{"id":"sched","model":"bert-large","cluster":{"preset":"a40","nodes":1,"gpus_per_node":4},"sweep":{"global_batch":4,"profile_iters":1,"schedule_axis":true}}"#;
    let (lines, _) = run_lines(line, &opts_with_workers(2));
    let j = parse(&lines[0]);
    let cands = result_field(&j, "candidates").as_arr().unwrap();
    let mut schedules: Vec<&str> = cands
        .iter()
        .filter_map(|c| c.get("schedule").and_then(Json::as_str))
        .collect();
    schedules.sort();
    schedules.dedup();
    assert!(
        schedules.len() >= 3,
        "schedule axis must enumerate dapple/gpipe/naive, got {schedules:?}"
    );
    let attr = result_field(&j, "schedule_attribution");
    assert!(attr.get("winning_schedule").and_then(Json::as_str).is_some());
    assert!(attr.get("schedule_speedup").and_then(Json::as_f64).unwrap() >= 1.0);
    assert!(attr.get("strategy_speedup").and_then(Json::as_f64).unwrap() >= 1.0);
}

#[test]
fn placement_opt_request_reports_pruning_and_optimized_tables() {
    // 2x2 mixed fleet: the symmetry-reduced table space is tiny (C(4,2)
    // = 6), so the optimizer enumerates it exhaustively
    let line = r#"{"id":"opt","model":"bert-large","cluster":{"preset":"a40-a10","nodes":2,"gpus_per_node":2},"sweep":{"global_batch":4,"profile_iters":1,"placement_axis":true,"placement_opt":true,"prune":true,"prune_epochs":2,"beam":2}}"#;
    let (lines, _) = run_lines(line, &opts_with_workers(2));
    let j = parse(&lines[0]);
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j}");

    // the pruning-accounting block is surfaced and self-consistent
    let pruning = result_field(&j, "pruning");
    let field = |k: &str| pruning.get(k).and_then(Json::as_f64).unwrap();
    let cands = result_field(&j, "candidates").as_arr().unwrap();
    assert_eq!(field("generated") as usize, cands.len());
    assert_eq!(
        field("generated"),
        field("bound_pruned") + field("epoch_repruned") + field("evaluated")
    );
    assert!(field("gpu_seconds_avoided") >= 0.0);

    // optimized candidates carry their rank->device table
    let optimized: Vec<&Json> = cands
        .iter()
        .filter(|c| c.get("placement").and_then(Json::as_str) == Some("optimized"))
        .collect();
    assert!(!optimized.is_empty(), "no optimized candidates in {j}");
    for c in &optimized {
        let t = c.get("table").and_then(Json::as_arr).expect("table array");
        let mut devs: Vec<usize> = t.iter().filter_map(Json::as_usize).collect();
        devs.sort_unstable();
        assert_eq!(devs, (0..4).collect::<Vec<_>>(), "{c}");
    }
    // and best names its placement
    assert!(result_field(&j, "best")
        .get("placement")
        .and_then(Json::as_str)
        .is_some());

    // responses stay bit-identical across worker counts with the
    // optimizer and adaptive epochs on
    let (again, _) = run_lines(line, &opts_with_workers(1));
    assert_eq!(lines, again);
}

#[test]
fn placement_opt_fields_are_strictly_validated() {
    for body in [
        r#""sweep":{"placement_opt":"yes"}"#,
        r#""sweep":{"prune_epochs":0}"#,
        r#""sweep":{"beam":0}"#,
        r#""sweep":{"beem":2}"#,
    ] {
        let line = format!(r#"{{"model":"bert-large","cluster":{{"preset":"a40"}},{body}}}"#);
        let (lines, _) = run_lines(&line, &opts_with_workers(1));
        let j = parse(&lines[0]);
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false), "{body}");
        assert_eq!(
            j.get("error").unwrap().get("kind").and_then(Json::as_str),
            Some("bad_request"),
            "{body}"
        );
    }
}

#[test]
fn capacity_less_responses_carry_no_memory_vocabulary() {
    // byte-identity with pre-memory builds: unless a capacity or a memory
    // axis is in play, none of the memory fields may appear anywhere in
    // the response stream
    let (lines, _) = run_lines(&small_sweep("plain", 4), &opts_with_workers(2));
    assert_eq!(lines.len(), 1);
    assert_eq!(parse(&lines[0]).get("ok").and_then(Json::as_bool), Some(true));
    for word in [
        "peak_bytes",
        "memory_pruned",
        "memory_gpu_seconds_avoided",
        "recompute",
        "zero_stage",
        "\"fits\"",
        "\"oom\"",
    ] {
        assert!(
            !lines[0].contains(word),
            "capacity-less response leaked '{word}': {}",
            lines[0]
        );
    }
}

/// A memory-constrained sweep: 3 GB cap on a 4-device A40 preset, with
/// both memory axes enumerated.
fn capped_sweep(id: &str) -> String {
    format!(
        r#"{{"id":"{id}","op":"sweep","model":"bert-large","cluster":{{"preset":"a40","nodes":1,"gpus_per_node":4,"capacity_bytes":3000000000}},"sweep":{{"global_batch":4,"profile_iters":1,"recompute_axis":true,"zero_axis":true}}}}"#
    )
}

#[test]
fn memory_constrained_sweep_reports_oom_placeholders_and_a_feasible_best() {
    let (lines, _) = run_lines(&capped_sweep("cap"), &opts_with_workers(2));
    let j = parse(&lines[0]);
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j}");

    // pruning identity now includes the memory stage at the head
    let pruning = result_field(&j, "pruning");
    let field = |k: &str| pruning.get(k).and_then(Json::as_f64).unwrap();
    assert!(field("memory_pruned") >= 1.0, "3 GB must OOM something: {j}");
    assert_eq!(
        field("generated"),
        field("memory_pruned")
            + field("bound_pruned")
            + field("epoch_repruned")
            + field("evaluated")
    );
    assert!(field("memory_gpu_seconds_avoided") >= 0.0);
    assert!(field("gpu_seconds_avoided") >= field("memory_gpu_seconds_avoided"));

    // every oom placeholder is a deterministic tombstone
    let cands = result_field(&j, "candidates").as_arr().unwrap();
    let mut ooms = 0;
    for c in cands {
        let fits = c.get("fits").and_then(Json::as_bool).unwrap();
        let peak = c.get("peak_bytes").and_then(Json::as_f64).unwrap();
        assert!(c.get("recompute").and_then(Json::as_str).is_some());
        assert!(c.get("zero_stage").and_then(Json::as_usize).is_some());
        if !fits {
            assert_eq!(c.get("reason").and_then(Json::as_str), Some("oom"), "{c}");
            assert_eq!(c.get("reachable").and_then(Json::as_bool), Some(false));
            assert_eq!(c.get("pruned").and_then(Json::as_bool), Some(true));
            assert!(peak > 3e9, "{c}");
            ooms += 1;
        }
    }
    assert!(ooms >= 1, "{j}");
    // and the winner actually fits
    let best = result_field(&j, "best");
    assert!(best.get("peak_bytes").and_then(Json::as_f64).unwrap() <= 3e9);

    // byte-identity across worker counts, memory stage and axes on
    for workers in [1, 4] {
        let (again, _) = run_lines(&capped_sweep("cap"), &opts_with_workers(workers));
        assert_eq!(lines, again, "{workers} workers diverged");
    }
}

#[test]
fn an_all_oom_space_ranks_nothing_but_answers_cleanly() {
    // 1-byte capacity: every candidate is infeasible; the response is
    // still ok:true, with no best/worst/speedup and zero evaluated
    let line = r#"{"id":"void","op":"sweep","model":"bert-large","cluster":{"preset":"a40","nodes":1,"gpus_per_node":4,"capacity_bytes":1},"sweep":{"global_batch":4,"profile_iters":1}}"#;
    let (lines, summary) = run_lines(line, &opts_with_workers(2));
    let j = parse(&lines[0]);
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j}");
    assert_eq!(summary.errors, 0);
    let result = j.get("result").unwrap();
    assert!(result.get("best").is_none(), "nothing fits: {j}");
    assert!(result.get("worst").is_none());
    assert!(result.get("speedup").is_none());
    let pruning = result_field(&j, "pruning");
    let cands = result_field(&j, "candidates").as_arr().unwrap();
    assert_eq!(
        pruning.get("memory_pruned").and_then(Json::as_usize),
        Some(cands.len())
    );
    assert_eq!(pruning.get("evaluated").and_then(Json::as_usize), Some(0));
    for c in cands {
        assert_eq!(c.get("fits").and_then(Json::as_bool), Some(false), "{c}");
        assert_eq!(c.get("reason").and_then(Json::as_str), Some("oom"));
    }
    // no profiling happened: the whole space was discarded for free
    let cache = result_field(&j, "cache");
    assert_eq!(cache.get("gpu_seconds").and_then(Json::as_f64), Some(0.0));
}

#[test]
fn memory_fields_are_strictly_validated() {
    for (body, cluster) in [
        (r#""sweep":{"recompute_axis":1}"#, r#"{"preset":"a40"}"#),
        (r#""sweep":{"zero_axis":"on"}"#, r#"{"preset":"a40"}"#),
        (r#""sweep":{"memory":0}"#, r#"{"preset":"a40"}"#),
        (
            r#""sweep":{}"#,
            r#"{"preset":"a40","capacity_bytes":"48GiB"}"#,
        ),
        (r#""sweep":{}"#, r#"{"preset":"a40","capacity_bytes":0}"#),
        (r#""sweep":{}"#, r#"{"preset":"a40","capacity_bytes":1.5}"#),
    ] {
        let line = format!(r#"{{"model":"bert-large","cluster":{cluster},{body}}}"#);
        let (lines, _) = run_lines(&line, &opts_with_workers(1));
        let j = parse(&lines[0]);
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false), "{line}");
        assert_eq!(error_kind(&j), "bad_request", "{line}");
    }
}

#[test]
fn save_interval_persists_snapshots_while_the_daemon_runs() {
    use std::io::{BufReader, Read};
    use std::sync::mpsc;
    use std::time::{Duration, Instant};

    /// Blocks between chunks like a live client connection, so the daemon
    /// stays up while the test inspects the cache dir.
    struct ChannelReader {
        rx: mpsc::Receiver<Vec<u8>>,
        buf: Vec<u8>,
        pos: usize,
    }
    impl Read for ChannelReader {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.buf.len() {
                match self.rx.recv() {
                    Ok(b) => {
                        self.buf = b;
                        self.pos = 0;
                    }
                    Err(_) => return Ok(0), // sender dropped = EOF
                }
            }
            let n = (self.buf.len() - self.pos).min(out.len());
            out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    let dir = fresh_cache_dir("interval");
    let opts = ServeOpts {
        workers: 1,
        cache_dir: Some(dir.clone()),
        save_interval: Some(Duration::from_millis(50)),
        ..ServeOpts::default()
    };
    let (tx, rx) = mpsc::channel::<Vec<u8>>();
    let daemon = std::thread::spawn({
        let opts = opts.clone();
        move || {
            serve_ndjson(
                BufReader::new(ChannelReader {
                    rx,
                    buf: Vec::new(),
                    pos: 0,
                }),
                std::io::sink(),
                &opts,
            )
        }
    });

    // one sweep fills a cache; the daemon then idles (no EOF yet) and the
    // periodic saver must persist a snapshot on its own
    tx.send(format!("{}\n", small_sweep("s", 4)).into_bytes())
        .unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    let snapshot_on_disk = loop {
        let files: Vec<String> = std::fs::read_dir(&dir)
            .map(|rd| {
                rd.map(|e| e.unwrap().file_name().into_string().unwrap())
                    .collect()
            })
            .unwrap_or_default();
        if files
            .iter()
            .any(|f| f.starts_with("cache-") && f.ends_with(".json"))
        {
            break true;
        }
        if Instant::now() > deadline {
            break false;
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    assert!(
        snapshot_on_disk,
        "periodic saver wrote no snapshot while the daemon was live"
    );

    drop(tx); // EOF: drain and exit
    let summary = daemon.join().unwrap();
    assert_eq!(summary.sweeps, 1);
    assert_eq!(summary.snapshots_saved, 1, "final save still happens");
    // atomic writes: with the saver stopped, no torn .tmp is left behind
    let leftover: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .filter(|f| f.ends_with(".tmp"))
        .collect();
    assert!(leftover.is_empty(), "leftover tmp files: {leftover:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tcp_transport_serves_and_shuts_down() {
    use std::io::{BufRead, BufReader, Write};

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let daemon = std::thread::spawn(move || {
        serve_tcp(listener, &opts_with_workers(2)).unwrap()
    });

    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    writeln!(stream, r#"{{"id":"t0","op":"ping"}}"#).unwrap();
    writeln!(stream, "{}", small_sweep("t1", 4)).unwrap();
    writeln!(stream, r#"{{"id":"t2","op":"shutdown"}}"#).unwrap();
    stream.flush().unwrap();

    let reader = BufReader::new(stream.try_clone().unwrap());
    let lines: Vec<String> = reader.lines().take(3).map(|l| l.unwrap()).collect();
    assert_eq!(lines.len(), 3);
    assert_eq!(parse(&lines[0]).get("id").and_then(Json::as_str), Some("t0"));
    let sweep = parse(&lines[1]);
    assert_eq!(sweep.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(
        result_field(&sweep, "candidates").as_arr().unwrap().len(),
        6
    );
    assert_eq!(parse(&lines[2]).get("id").and_then(Json::as_str), Some("t2"));

    let summary = daemon.join().unwrap();
    assert_eq!(summary.requests, 3);
    assert_eq!(summary.sweeps, 1);
}

fn error_kind<'a>(j: &'a Json) -> &'a str {
    j.get("error")
        .and_then(|e| e.get("kind"))
        .and_then(Json::as_str)
        .unwrap_or_else(|| panic!("no error.kind in {j}"))
}

fn cancel_outcome<'a>(j: &'a Json) -> &'a str {
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j}");
    assert_eq!(
        result_field(j, "op").as_str(),
        Some("cancel"),
        "not a cancel ack: {j}"
    );
    result_field(j, "outcome").as_str().unwrap()
}

#[test]
fn cancel_of_a_queued_sweep_aborts_it_with_a_structured_error() {
    // one worker: the head sweep occupies it while the reader (which runs
    // far ahead of any sweep) queues the victim and then cancels it
    let input = [
        small_sweep("head", 8),
        small_sweep("victim", 4),
        r#"{"id":"c","op":"cancel","target":"victim"}"#.to_string(),
        r#"{"id":"p","op":"ping"}"#.to_string(),
    ]
    .join("\n");
    let (lines, summary) = run_lines(&input, &opts_with_workers(1));
    assert_eq!(lines.len(), 4, "{lines:?}");
    // per-connection order: head, victim, cancel ack, pong
    for (i, id) in ["head", "victim", "c", "p"].iter().enumerate() {
        assert_eq!(
            parse(&lines[i]).get("id").and_then(Json::as_str),
            Some(*id),
            "response {i} out of order: {lines:?}"
        );
    }
    let head = parse(&lines[0]);
    assert_eq!(head.get("ok").and_then(Json::as_bool), Some(true));
    let victim = parse(&lines[1]);
    assert_eq!(victim.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(error_kind(&victim), "cancelled", "{victim}");
    assert_eq!(cancel_outcome(&parse(&lines[2])), "cancelled_queued");
    assert_eq!(parse(&lines[3]).get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(summary.sweeps, 1, "the cancelled sweep never produced a report");
    assert_eq!(summary.errors, 1);
}

#[test]
fn cancel_of_an_unknown_or_finished_target_is_not_found() {
    let input = [
        r#"{"id":"c0","op":"cancel","target":"ghost"}"#.to_string(),
        small_sweep("done", 4),
        r#"{"id":"c1","op":"cancel","target":"done"}"#.to_string(),
    ]
    .join("\n");
    let (lines, _) = run_lines(&input, &opts_with_workers(1));
    assert_eq!(lines.len(), 3);
    assert_eq!(cancel_outcome(&parse(&lines[0])), "not_found");
    // "done" may still be queued/running when the reader cancels it, so
    // only the *never-submitted* target has a deterministic outcome; the
    // ack itself must still be well-formed either way
    let late = cancel_outcome(&parse(&lines[2]));
    assert!(
        ["not_found", "cancelled_queued", "cancelling"].contains(&late),
        "unexpected outcome {late}"
    );
}

#[test]
fn full_admission_queue_sheds_load_with_structured_unavailable() {
    // one worker + a queue bound of 1: the reader races far ahead of the
    // sweeps, so at least one of the 4 admitted sweeps must overflow
    let opts = ServeOpts {
        workers: 1,
        max_queue: 1,
        ..ServeOpts::default()
    };
    let input = [
        small_sweep("s0", 4),
        small_sweep("s1", 4),
        small_sweep("s2", 4),
        small_sweep("s3", 4),
        r#"{"id":"p","op":"ping"}"#.to_string(),
    ]
    .join("\n");
    let (lines, summary) = run_lines(&input, &opts);
    assert_eq!(lines.len(), 5, "every admitted request is answered: {lines:?}");
    assert_eq!(summary.requests, 5);
    let mut shed = 0;
    for (i, id) in ["s0", "s1", "s2", "s3"].iter().enumerate() {
        let j = parse(&lines[i]);
        assert_eq!(j.get("id").and_then(Json::as_str), Some(*id), "{lines:?}");
        if j.get("ok").and_then(Json::as_bool) == Some(true) {
            assert!(result_field(&j, "best").get("throughput").is_some());
        } else {
            assert_eq!(error_kind(&j), "unavailable", "{j}");
            let msg = j
                .get("error")
                .unwrap()
                .get("message")
                .and_then(Json::as_str)
                .unwrap();
            assert!(msg.contains("queue is full"), "{msg}");
            shed += 1;
        }
    }
    assert!(shed >= 1, "queue bound 1 with 4 burst sweeps must shed: {lines:?}");
    assert!(shed <= 3, "the head sweep always runs: {lines:?}");
    // control ops bypass the queue entirely: the ping works regardless
    assert_eq!(parse(&lines[4]).get("ok").and_then(Json::as_bool), Some(true));
}

#[test]
fn injected_worker_panic_poisons_locks_but_daemon_keeps_answering() {
    // the "boom" sweep panics inside the worker *while holding the
    // profile-cache entries lock*; every later request must recover the
    // poisoned lock and answer normally (ISSUE 6 satellite: a poisoned
    // mutex used to unwind every subsequent .lock().unwrap())
    let opts = ServeOpts {
        workers: 1,
        panic_inject_id: Some("boom".to_string()),
        ..ServeOpts::default()
    };
    let input = [
        small_sweep("boom", 4),
        small_sweep("after", 4),
        r#"{"id":"st","op":"stats"}"#.to_string(),
        r#"{"id":"p","op":"ping"}"#.to_string(),
    ]
    .join("\n");
    let (lines, summary) = run_lines(&input, &opts);
    assert_eq!(lines.len(), 4, "{lines:?}");
    let boom = parse(&lines[0]);
    assert_eq!(boom.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(error_kind(&boom), "internal", "{boom}");
    assert!(
        boom.get("error")
            .unwrap()
            .get("message")
            .and_then(Json::as_str)
            .unwrap()
            .contains("injected panic"),
        "{boom}"
    );
    // same fingerprint, same (now-poisoned, recovered) cache: still works
    let after = parse(&lines[1]);
    assert_eq!(after.get("ok").and_then(Json::as_bool), Some(true), "{after}");
    assert_eq!(
        result_field(&after, "candidates").as_arr().unwrap().len(),
        6
    );
    assert_eq!(parse(&lines[2]).get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(parse(&lines[3]).get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(summary.sweeps, 1);
    assert_eq!(summary.errors, 1);
}
