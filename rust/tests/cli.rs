//! CLI integration: drive the `distsim` binary like a user would.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_distsim"))
}

#[test]
fn help_lists_subcommands() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["simulate", "search", "calibrate", "exp", "models"] {
        assert!(text.contains(cmd), "help missing '{cmd}'");
    }
}

#[test]
fn no_args_prints_help_and_fails() {
    let out = bin().output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn models_lists_the_zoo() {
    let out = bin().arg("models").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for m in ["bert-large", "gpt2-345m", "t5", "bert-exlarge", "gpt-145b"] {
        assert!(text.contains(m), "models missing '{m}'");
    }
}

#[test]
fn simulate_reports_prediction_and_error() {
    let out = bin()
        .args([
            "simulate",
            "--model",
            "bert-large",
            "--strategy",
            "2M2P2D",
            "--profile-iters",
            "10",
            "--gt",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("DistSim predicted batch time"));
    assert!(text.contains("ground-truth batch time"));
}

#[test]
fn simulate_writes_chrome_trace() {
    let trace = std::env::temp_dir().join("distsim_cli_trace.json");
    let out = bin()
        .args([
            "simulate",
            "--strategy",
            "1M2P2D",
            "--profile-iters",
            "5",
            "--trace",
            trace.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = std::fs::read_to_string(&trace).unwrap();
    assert!(text.contains("traceEvents"));
}

#[test]
fn unknown_command_fails_with_message() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn bad_strategy_rejected() {
    let out = bin()
        .args(["simulate", "--strategy", "9X"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}
