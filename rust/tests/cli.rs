//! CLI integration: drive the `distsim` binary like a user would.

use std::io::Write;
use std::process::{Command, Stdio};

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_distsim"))
}

#[test]
fn help_lists_subcommands() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["simulate", "search", "calibrate", "exp", "models"] {
        assert!(text.contains(cmd), "help missing '{cmd}'");
    }
}

#[test]
fn no_args_prints_help_and_fails() {
    let out = bin().output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn models_lists_the_zoo() {
    let out = bin().arg("models").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for m in ["bert-large", "gpt2-345m", "t5", "bert-exlarge", "gpt-145b"] {
        assert!(text.contains(m), "models missing '{m}'");
    }
}

#[test]
fn simulate_reports_prediction_and_error() {
    let out = bin()
        .args([
            "simulate",
            "--model",
            "bert-large",
            "--strategy",
            "2M2P2D",
            "--profile-iters",
            "10",
            "--gt",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("DistSim predicted batch time"));
    assert!(text.contains("ground-truth batch time"));
}

#[test]
fn simulate_writes_chrome_trace() {
    let trace = std::env::temp_dir().join("distsim_cli_trace.json");
    let out = bin()
        .args([
            "simulate",
            "--strategy",
            "1M2P2D",
            "--profile-iters",
            "5",
            "--trace",
            trace.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = std::fs::read_to_string(&trace).unwrap();
    assert!(text.contains("traceEvents"));
}

#[test]
fn unknown_command_fails_with_message() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn errors_are_one_line_json_not_backtraces() {
    // malformed request file: exit non-zero with a parseable error line
    let out = bin()
        .args(["ask", "--file", "/definitely/not/a/file.ndjson"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    let line = stderr.lines().next().expect("an error line");
    let j = distsim::config::Json::parse(line)
        .unwrap_or_else(|e| panic!("stderr not JSON ({e}): {stderr}"));
    assert_eq!(
        j.get("error").unwrap().get("kind").and_then(|k| k.as_str()),
        Some("cli")
    );
    assert!(!stderr.contains("panicked"), "{stderr}");
}

#[test]
fn serve_stdio_answers_a_piped_request() {
    let mut child = bin()
        .args(["serve", "--stdio", "--workers", "2"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    child
        .stdin
        .take()
        .unwrap()
        .write_all(
            concat!(
                r#"{"id":"smoke","op":"sweep","model":"bert-large","#,
                r#""cluster":{"preset":"a40","nodes":1,"gpus_per_node":4},"#,
                r#""sweep":{"global_batch":4,"profile_iters":1}}"#,
                "\n"
            )
            .as_bytes(),
        )
        .unwrap(); // dropping stdin sends EOF: the daemon drains and exits
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let line = stdout.lines().next().expect("one response line");
    let j = distsim::config::Json::parse(line).unwrap();
    assert_eq!(j.get("id").and_then(|v| v.as_str()), Some("smoke"));
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));
}

#[test]
fn ask_runs_a_local_what_if_query() {
    let out = bin()
        .args([
            "ask",
            "--model",
            "bert-large",
            "--nodes",
            "1",
            "--gpus-per-node",
            "4",
            "--global-batch",
            "4",
            "--profile-iters",
            "1",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let j = distsim::config::Json::parse(stdout.lines().next().unwrap()).unwrap();
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert!(j.get("result").unwrap().get("best").is_some());
}

#[test]
fn search_cache_file_warms_a_second_run() {
    let path = std::env::temp_dir().join(format!(
        "distsim_cli_cache_{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let args = |p: &str| {
        vec![
            "search".to_string(),
            "--model".into(),
            "bert-large".into(),
            "--nodes".into(),
            "1".into(),
            "--gpus-per-node".into(),
            "4".into(),
            "--global-batch".into(),
            "4".into(),
            "--profile-iters".into(),
            "2".into(),
            "--cache-file".into(),
            p.into(),
        ]
    };
    let cold = bin().args(args(path.to_str().unwrap())).output().unwrap();
    assert!(cold.status.success(), "{}", String::from_utf8_lossy(&cold.stderr));
    assert!(path.exists(), "first run must write the snapshot");
    let warm = bin().args(args(path.to_str().unwrap())).output().unwrap();
    assert!(warm.status.success());
    let text = String::from_utf8_lossy(&warm.stdout);
    assert!(
        text.contains("100% hit rate"),
        "second run must profile nothing: {text}"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn stale_snapshot_is_rejected_with_a_versioned_json_error_and_upgraded() {
    // a pre-heterogeneity (version-1) snapshot must never serve costs:
    // the search reports one parseable JSON error line on stderr, runs
    // cold, and overwrites the file with a current-version snapshot
    let path = std::env::temp_dir().join(format!(
        "distsim_cli_stale_cache_{}.json",
        std::process::id()
    ));
    std::fs::write(
        &path,
        r#"{"kind":"distsim-profile-cache","version":1,"entries":[]}"#,
    )
    .unwrap();
    let out = bin()
        .args([
            "search",
            "--model",
            "bert-large",
            "--nodes",
            "1",
            "--gpus-per-node",
            "4",
            "--global-batch",
            "4",
            "--profile-iters",
            "1",
            "--cache-file",
            path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stderr = String::from_utf8_lossy(&out.stderr);
    let line = stderr
        .lines()
        .find(|l| l.starts_with('{'))
        .expect("a JSON error line on stderr");
    let j = distsim::config::Json::parse(line).unwrap();
    let msg = j
        .get("error")
        .unwrap()
        .get("message")
        .and_then(|m| m.as_str())
        .unwrap();
    assert!(msg.contains("version 1 predates"), "{msg}");
    // the file was upgraded to the current snapshot version
    let upgraded = std::fs::read_to_string(&path).unwrap();
    assert!(
        upgraded.contains(&format!("\"version\":{}", distsim::search::SNAPSHOT_VERSION)),
        "stale snapshot not upgraded: {}",
        &upgraded[..upgraded.len().min(200)]
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn search_placement_axis_on_a_mixed_fleet_prints_attribution() {
    let out = bin()
        .args([
            "search",
            "--model",
            "bert-large",
            "--device",
            "a40-a10",
            "--nodes",
            "2",
            "--gpus-per-node",
            "2",
            "--global-batch",
            "4",
            "--profile-iters",
            "1",
            "--placement-axis",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("placement axis:"), "{text}");
    assert!(text.contains("fast_first"), "{text}");
    assert!(text.contains("interleaved"), "{text}");
}

#[test]
fn search_placement_opt_prints_the_pruning_block_and_optimized_rows() {
    let out = bin()
        .args([
            "search",
            "--model",
            "bert-large",
            "--device",
            "a40-a10",
            "--nodes",
            "2",
            "--gpus-per-node",
            "2",
            "--global-batch",
            "4",
            "--profile-iters",
            "1",
            "--placement-opt",
            "--prune",
            "--prune-epochs",
            "2",
            "--beam",
            "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    // the Table-3-style pruning accounting block
    assert!(text.contains("pruning:"), "{text}");
    assert!(text.contains("bound-pruned"), "{text}");
    assert!(text.contains("epoch-repruned"), "{text}");
    assert!(text.contains("gpu-s avoided"), "{text}");
    // optimizer candidates appear as rows
    assert!(text.contains("optimized"), "{text}");
}

#[test]
fn search_with_capacity_prints_the_memory_block_and_oom_rows() {
    let out = bin()
        .args([
            "search",
            "--model",
            "bert-large",
            "--device",
            "a40",
            "--nodes",
            "1",
            "--gpus-per-node",
            "4",
            "--global-batch",
            "4",
            "--profile-iters",
            "1",
            "--capacity-gib",
            "2.8",
            "--recompute-axis",
            "--zero-axis",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    // infeasible rows are marked, the accounting block is printed, and a
    // feasible winner still emerges
    assert!(text.contains("oom (peak"), "{text}");
    assert!(text.contains("memory:"), "{text}");
    assert!(text.contains("memory-pruned"), "{text}");
    assert!(text.contains("avoided by the memory stage"), "{text}");
    assert!(text.contains("best "), "{text}");
}

#[test]
fn search_where_nothing_fits_reports_no_winner_cleanly() {
    let out = bin()
        .args([
            "search",
            "--model",
            "bert-large",
            "--nodes",
            "1",
            "--gpus-per-node",
            "4",
            "--global-batch",
            "4",
            "--profile-iters",
            "1",
            "--capacity-gib",
            "0.001",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains("no reachable candidate"),
        "all-OOM space must rank nothing: {text}"
    );
    assert!(text.contains("oom (peak"), "{text}");
}

#[test]
fn ask_forwards_the_memory_flags_to_the_service() {
    let out = bin()
        .args([
            "ask",
            "--model",
            "bert-large",
            "--device",
            "a40",
            "--nodes",
            "1",
            "--gpus-per-node",
            "4",
            "--global-batch",
            "4",
            "--profile-iters",
            "1",
            "--capacity-gib",
            "2.8",
            "--zero-axis",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let j = distsim::config::Json::parse(stdout.lines().next().unwrap()).unwrap();
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true), "{stdout}");
    let result = j.get("result").unwrap();
    let pruning = result.get("pruning").unwrap();
    assert!(
        pruning
            .get("memory_pruned")
            .and_then(|v| v.as_usize())
            .unwrap()
            >= 1,
        "{stdout}"
    );
    assert!(stdout.contains("\"reason\":\"oom\""), "{stdout}");
    assert!(stdout.contains("zero_stage"), "{stdout}");
}

#[test]
fn bad_strategy_rejected() {
    let out = bin()
        .args(["simulate", "--strategy", "9X"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}
