//! Integration tests: the full DistSim pipeline against the ground-truth
//! engine, across the hybrid-strategy grid — the paper's headline accuracy
//! claims as assertions.

use distsim::cluster::ClusterSpec;
use distsim::config::RunConfig;
use distsim::exp::eval_cfg;
use distsim::metrics::{batch_time_error_pct, per_gpu_activity_error_pct};
use distsim::strategy::Strategy;
use distsim::util::stats;

fn cfg(model: &str, s: &str, profile_iters: usize) -> RunConfig {
    let mut c = RunConfig::new(
        model,
        Strategy::parse(s).unwrap(),
        ClusterSpec::a40_cluster(4, 4),
    );
    c.profile_iters = profile_iters;
    c
}

#[test]
fn batch_time_error_under_4pct_across_grid() {
    // Fig. 8's claim, asserted over the full strategy grid x 2 models.
    for model in ["bert-large", "gpt2-345m"] {
        for s in ["1M1P4D", "2M2P1D", "1M2P2D", "2M2P2D", "1M4P2D", "2M4P2D", "4M2P2D"] {
            let run = eval_cfg(&cfg(model, s, 50)).unwrap();
            let actual = run.gt.run_iteration(0);
            let err = batch_time_error_pct(&run.predicted, &actual);
            assert!(err < 4.0, "{model} {s}: batch-time error {err:.2}%");
        }
    }
}

#[test]
fn per_gpu_activity_error_under_5pct() {
    // Fig. 9's claim.
    for s in ["2M2P2D", "1M4P2D", "2M4P2D"] {
        let run = eval_cfg(&cfg("bert-large", s, 50)).unwrap();
        let actual = run.gt.run_iteration(0);
        let errs = per_gpu_activity_error_pct(&run.predicted, &actual);
        let worst = stats::max(&errs);
        assert!(worst < 5.0, "{s}: worst per-GPU error {worst:.2}%");
    }
}

#[test]
fn gpipe_and_dapple_both_model_accurately() {
    for sched in ["gpipe", "dapple"] {
        let mut c = cfg("bert-large", "1M4P1D", 50);
        c.schedule = sched.to_string();
        c.micro_batches = 8;
        let run = eval_cfg(&c).unwrap();
        let actual = run.gt.run_iteration(0);
        let err = batch_time_error_pct(&run.predicted, &actual);
        assert!(err < 4.0, "{sched}: error {err:.2}%");
    }
}

#[test]
fn t5_48_layer_model_works_end_to_end() {
    let run = eval_cfg(&cfg("t5", "2M4P2D", 30)).unwrap();
    let actual = run.gt.run_iteration(0);
    assert!(batch_time_error_pct(&run.predicted, &actual) < 4.0);
}

#[test]
fn prediction_is_deterministic() {
    let a = eval_cfg(&cfg("bert-large", "2M2P2D", 30)).unwrap();
    let b = eval_cfg(&cfg("bert-large", "2M2P2D", 30)).unwrap();
    assert_eq!(
        a.predicted.batch_time_us(),
        b.predicted.batch_time_us(),
        "same config + seed must give identical predictions"
    );
}

#[test]
fn span_counts_match_between_model_and_engine() {
    // the modeled timeline must be structurally identical to the real one:
    // same number of compute spans per device, same tags
    let run = eval_cfg(&cfg("bert-large", "2M4P2D", 10)).unwrap();
    let actual = run.gt.run_iteration(0);
    assert_eq!(run.predicted.n_devices, actual.n_devices);
    for d in 0..actual.n_devices {
        let p = run.predicted.device_comp_spans(d);
        let t = actual.device_comp_spans(d);
        assert_eq!(p.len(), t.len(), "device {d}");
        for (x, y) in p.iter().zip(t) {
            assert_eq!(x.tag, y.tag, "device {d}");
        }
    }
}

#[test]
fn property_any_valid_strategy_models_within_bounds() {
    // property sweep: random valid strategies on 16 devices, batch-time
    // error must stay under a loose 6% bound (4% is the tuned-grid claim)
    let strategies: Vec<Strategy> = Strategy::enumerate(16)
        .into_iter()
        .chain(Strategy::enumerate(8))
        .chain(Strategy::enumerate(4))
        .filter(|s| 16 % s.mp == 0 && s.mp <= 4 && s.pp <= 8)
        .collect();
    for s in strategies {
        let mut c = RunConfig::new("bert-large", s, ClusterSpec::a40_cluster(4, 4));
        c.profile_iters = 20;
        let run = eval_cfg(&c).unwrap();
        let actual = run.gt.run_iteration(0);
        let err = batch_time_error_pct(&run.predicted, &actual);
        assert!(err < 6.0, "{s}: error {err:.2}%");
    }
}

#[test]
fn failure_injection_unknown_schedule_rejected() {
    let mut c = cfg("bert-large", "1M2P2D", 5);
    c.schedule = "chimera".into();
    assert!(eval_cfg(&c).is_err());
}

#[test]
fn failure_injection_world_size_exceeds_cluster() {
    let c = cfg("bert-large", "4M4P4D", 5); // 64 > 16
    assert!(eval_cfg(&c).is_err());
}
