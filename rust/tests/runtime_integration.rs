//! PJRT integration: load the AOT artifacts (L2/L1 output) and execute
//! them from Rust. Requires `make artifacts`; every test self-skips when
//! the artifacts are absent so `cargo test` stays green pre-build.

use distsim::runtime::{artifacts_dir, Manifest, Runtime};

fn manifest_or_skip() -> Option<Manifest> {
    let dir = artifacts_dir();
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
            None
        }
    }
}

#[test]
fn manifest_has_all_event_kinds() {
    let Some(m) = manifest_or_skip() else { return };
    for kind in ["matmul", "layer_fwd", "layer_bwd", "attention"] {
        assert!(!m.by_kind(kind).is_empty(), "missing artifacts of kind {kind}");
    }
    // every MP degree the paper's strategies use has a layer artifact
    for mp in [1, 2, 4] {
        assert!(
            m.by_name(&format!("layer_h1024_mp{mp}_fwd")).is_some(),
            "missing h1024 mp{mp} fwd artifact"
        );
    }
}

#[test]
fn pjrt_loads_and_executes_matmul_artifact() {
    let Some(m) = manifest_or_skip() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    assert!(rt.platform().to_lowercase().contains("cpu")
        || rt.platform().to_lowercase().contains("host"));
    let spec = m.by_name("matmul_128").expect("matmul_128 artifact");
    let exe = rt.load(spec).expect("compile matmul HLO");
    let us = exe.run_once_us().expect("execute");
    assert!(us > 0.0 && us < 5e6, "implausible latency {us} us");
}

#[test]
fn pjrt_executes_pallas_layer_fwd_and_bwd() {
    // The full three-layer path: Pallas kernels (L1) inside the JAX layer
    // graph (L2), AOT-lowered and executed from Rust (L3).
    let Some(m) = manifest_or_skip() else { return };
    let rt = Runtime::cpu().unwrap();
    for name in ["layer_h1024_mp2_fwd", "layer_h1024_mp2_bwd"] {
        let spec = m.by_name(name).unwrap();
        let exe = rt.load(spec).unwrap();
        let us = exe.bench_us(2).unwrap();
        assert!(us > 0.0, "{name}: zero latency");
    }
}

#[test]
fn measured_latency_scales_with_flops() {
    // matmul_1024 has 512x the FLOPs of matmul_128; wall time must grow
    // substantially (not necessarily linearly on CPU caches).
    let Some(m) = manifest_or_skip() else { return };
    let rt = Runtime::cpu().unwrap();
    let small = rt.load(m.by_name("matmul_128").unwrap()).unwrap();
    let big = rt.load(m.by_name("matmul_1024").unwrap()).unwrap();
    let ts = small.bench_us(3).unwrap();
    let tb = big.bench_us(3).unwrap();
    assert!(tb > 5.0 * ts, "1024^3 matmul ({tb} us) should dwarf 128^3 ({ts} us)");
}

#[test]
fn calibration_fits_from_artifacts() {
    let Some(_) = manifest_or_skip() else { return };
    let mut cal =
        distsim::profile::calibrate::measure_artifacts(&artifacts_dir(), 2).unwrap();
    assert!(cal.host_gflops > 0.1, "host gflops {}", cal.host_gflops);
    let host_tflops = cal.host_gflops / 1e3;
    distsim::profile::calibrate::fit_scale(
        &mut cal,
        &distsim::cost::CostModel::default(),
        host_tflops,
    );
    assert!(cal.scale > 0.0 && cal.scale.is_finite());
}
