//! Docs-drift checks: `docs/FORMATS.md` is the normative spec of every
//! externally visible byte format, so the things the code accepts or
//! emits must appear there. These tests run as part of tier-1 (`cargo
//! test`), which is what `ci.sh` and the workflow execute — editing the
//! dispatcher without documenting the new surface fails CI.

use distsim::search::SNAPSHOT_VERSION;
use distsim::service::protocol::OPS;
use distsim::service::ErrorKind;

fn formats_md() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/FORMATS.md");
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("docs/FORMATS.md must exist ({path}): {e}"))
}

#[test]
fn every_dispatcher_op_is_documented() {
    let doc = formats_md();
    for op in OPS {
        assert!(
            doc.contains(&format!("`{op}`")),
            "service op `{op}` is accepted by the dispatcher but not \
             documented in docs/FORMATS.md"
        );
    }
}

#[test]
fn dispatcher_accepts_exactly_the_documented_ops() {
    use distsim::service::protocol::parse_line;
    // every listed op parses (sweep and cancel need their required fields)
    for op in OPS {
        let line = match op {
            "sweep" => format!(
                r#"{{"op":"{op}","model":"bert-large","cluster":{{"preset":"a40"}}}}"#
            ),
            "cancel" => format!(r#"{{"op":"{op}","target":"r1"}}"#),
            _ => format!(r#"{{"op":"{op}"}}"#),
        };
        assert!(parse_line(&line).is_ok(), "documented op '{op}' rejected");
    }
    // and nothing else does
    assert!(parse_line(r#"{"op":"frobnicate"}"#).is_err());
}

#[test]
fn admission_and_cancellation_contract_is_documented() {
    // ISSUE 6 surface: the per-connection delivery contract, the cancel
    // op's outcomes, the bounded admission queue and its CLI flag must
    // all be specified in docs/FORMATS.md
    let doc = formats_md();
    for word in [
        "per-connection",
        "target",
        "cancelled_queued",
        "cancelling",
        "not_found",
        "max-queue",
        "unavailable",
    ] {
        assert!(doc.contains(word), "'{word}' missing from docs/FORMATS.md");
    }
    // and the parser enforces what the spec says about `target`
    use distsim::service::protocol::parse_line;
    assert!(parse_line(r#"{"op":"cancel","target":"r1"}"#).is_ok());
    assert!(parse_line(r#"{"op":"cancel"}"#).is_err(), "target is required");
    assert!(
        parse_line(r#"{"op":"ping","target":"r1"}"#).is_err(),
        "target is cancel-only"
    );
}

#[test]
fn every_error_kind_is_documented() {
    let doc = formats_md();
    for kind in ErrorKind::ALL {
        assert!(
            doc.contains(&format!("`{}`", kind.name())),
            "error kind `{}` can be emitted but is not documented in \
             docs/FORMATS.md",
            kind.name()
        );
    }
}

#[test]
fn snapshot_format_and_version_are_documented() {
    let doc = formats_md();
    assert!(doc.contains("distsim-profile-cache"));
    assert!(
        doc.contains(&format!("`version` is `{SNAPSHOT_VERSION}`")),
        "docs/FORMATS.md must state the current snapshot version \
         ({SNAPSHOT_VERSION})"
    );
}

#[test]
fn bench_formats_are_documented() {
    let doc = formats_md();
    for name in [
        "BENCH_engine.json",
        "BENCH_service.json",
        "BENCH_placement.json",
        "BENCH_scenario.json",
        "BENCH_plan.json",
    ] {
        assert!(doc.contains(name), "{name} missing from docs/FORMATS.md");
    }
}

#[test]
fn placement_and_preset_vocabulary_is_documented() {
    let doc = formats_md();
    for word in ["fast_first", "interleaved", "a40-a10", "per_kind", "kind_of_device"] {
        assert!(doc.contains(word), "'{word}' missing from docs/FORMATS.md");
    }
}

#[test]
fn placement_optimizer_and_pruning_schema_is_documented() {
    // ISSUE 5 surface: the staged pipeline's request fields, the
    // optimizer's placement vocabulary, and the pruning-accounting
    // response object must all be specified in docs/FORMATS.md
    let doc = formats_md();
    for word in [
        "placement_opt",
        "prune_epochs",
        "beam",
        "optimized",
        "bound_pruned",
        "epoch_repruned",
        "gpu_seconds_avoided",
        "save-interval",
    ] {
        assert!(doc.contains(word), "'{word}' missing from docs/FORMATS.md");
    }
    // and the parser accepts exactly what the spec names
    use distsim::service::protocol::parse_line;
    let ok = r#"{"model":"bert-large","cluster":{"preset":"a40-a10","nodes":2},"sweep":{"placement_opt":true,"prune_epochs":2,"beam":3}}"#;
    assert!(parse_line(ok).is_ok());
}

#[test]
fn scenario_schema_is_documented() {
    // ISSUE 7 surface: the ScenarioSpec request schema, the scenario
    // response fields, and the stats counters must all be specified in
    // docs/FORMATS.md
    let doc = formats_md();
    for word in [
        "straggler_episodes",
        "link_episodes",
        "checkpoint_interval_us",
        "dp_delta",
        "reshard_us",
        "scenario_throughput",
        "robustness",
        "regret",
        "straggler_slowdown",
        "link_slowdown",
        "restart_penalty_us",
        "episodes",
        "scenario-file",
    ] {
        assert!(doc.contains(word), "'{word}' missing from docs/FORMATS.md");
    }
    // and the parser accepts exactly what the spec names
    use distsim::service::protocol::parse_line;
    let ok = r#"{"model":"bert-large","cluster":{"preset":"a40"},"sweep":{"scenario":{"stragglers":[{"device":0,"factor":1.5}],"resize":{"dp_delta":-1,"reshard_us":100}}}}"#;
    assert!(parse_line(ok).is_ok());
    let typo = r#"{"model":"bert-large","cluster":{"preset":"a40"},"sweep":{"scenario":{"straggler":[{"device":0,"factor":1.5}]}}}"#;
    assert!(parse_line(typo).is_err(), "unknown scenario key must be rejected");
}

#[test]
fn memory_model_schema_is_documented() {
    // ISSUE 9 surface: the per-rank memory model's request fields, the
    // per-candidate verdict fields, the oom placeholder, the pruning
    // counters, the CLI flags and the metrics family must all be
    // specified in docs/FORMATS.md
    let doc = formats_md();
    for word in [
        "capacity_bytes",
        "recompute_axis",
        "zero_axis",
        "`memory`",
        "peak_bytes",
        "`fits`",
        "`oom`",
        "recompute",
        "zero_stage",
        "memory_pruned",
        "memory_gpu_seconds_avoided",
        "pruning_memory_pruned_total",
        "capacity-gib",
        "recompute-axis",
        "zero-axis",
    ] {
        assert!(doc.contains(word), "'{word}' missing from docs/FORMATS.md");
    }
    // and the parser accepts exactly what the spec names
    use distsim::service::protocol::parse_line;
    let ok = r#"{"model":"bert-large","cluster":{"preset":"a40","capacity_bytes":3000000000},"sweep":{"recompute_axis":true,"zero_axis":true,"memory":true}}"#;
    assert!(parse_line(ok).is_ok());
    for bad in [
        r#"{"model":"bert-large","cluster":{"preset":"a40","capacity_bytes":"48GiB"}}"#,
        r#"{"model":"bert-large","cluster":{"preset":"a40"},"sweep":{"recompute_axis":1}}"#,
        r#"{"model":"bert-large","cluster":{"preset":"a40"},"sweep":{"zero_axis":"yes"}}"#,
        r#"{"model":"bert-large","cluster":{"preset":"a40"},"sweep":{"memory":0.5}}"#,
    ] {
        assert!(parse_line(bad).is_err(), "must reject: {bad}");
    }
}

#[test]
fn plan_cache_schema_is_documented() {
    // ISSUE 10 surface: the stats response's plan-accounting block, the
    // plan metric families, and the CLI flag must all be specified in
    // docs/FORMATS.md (the metric names are additionally covered by
    // `telemetry_surfaces_are_documented` via `ServiceMetrics::names`)
    let doc = formats_md();
    for word in [
        "`plans`",
        "`compiles`",
        "`hits`",
        "`partial`",
        "plan_compiles_total",
        "plan_hits_total",
        "plan_partial_reuse_total",
        "plan_compile_us",
        "plan-cache",
    ] {
        assert!(doc.contains(word), "'{word}' missing from docs/FORMATS.md");
    }
    // the plan cache is daemon-transparent: no new request keys, so the
    // schema a plan-cached daemon accepts is exactly the documented one
    use distsim::service::protocol::parse_line;
    assert!(
        parse_line(r#"{"op":"sweep","model":"bert-large","cluster":{"preset":"a40"},"sweep":{"plan":true}}"#)
            .is_err(),
        "the plan cache must not grow the request schema"
    );
}

#[test]
fn telemetry_surfaces_are_documented() {
    // ISSUE 8 surface: the `metrics` op's two exposition forms, every
    // metric family name, the trace block and its span vocabulary, the
    // stderr log-event schema, and the new serve flags must all be
    // specified in docs/FORMATS.md
    let doc = formats_md();
    for name in distsim::telemetry::ServiceMetrics::new().names() {
        assert!(
            doc.contains(name),
            "metric family '{name}' is exposed by the metrics op but not \
             documented in docs/FORMATS.md"
        );
    }
    for phase in distsim::telemetry::TRACE_PHASES {
        assert!(
            doc.contains(&format!("`{phase}`")),
            "trace span '{phase}' can be emitted but is not documented in \
             docs/FORMATS.md"
        );
    }
    for event in distsim::telemetry::LOG_EVENTS {
        assert!(
            doc.contains(&format!("`{event}`")),
            "log event '{event}' can be emitted but is not documented in \
             docs/FORMATS.md"
        );
    }
    for word in [
        "log-level",
        "trace-dir",
        "prometheus",
        "distsim_",
        "quantum_us",
        "deterministic",
        "depth",
        "max_queue",
        "trace-conn",
        "ts_ms",
    ] {
        assert!(doc.contains(word), "'{word}' missing from docs/FORMATS.md");
    }
    // and the parser accepts exactly what the spec names
    use distsim::service::protocol::parse_line;
    assert!(parse_line(r#"{"op":"metrics"}"#).is_ok());
    let traced = r#"{"model":"bert-large","cluster":{"preset":"a40"},"sweep":{"trace":true}}"#;
    assert!(parse_line(traced).is_ok());
    let typo = r#"{"model":"bert-large","cluster":{"preset":"a40"},"sweep":{"trace":1}}"#;
    assert!(parse_line(typo).is_err(), "trace must be a bool");
}
