//! Golden equivalence suite for the columnar indexed Timeline and the
//! scratch-reuse engine path (ISSUE 2).
//!
//! The indexed store must yield **byte-identical** metric values (batch
//! time, per-GPU activity error, stage timestamps, bubble ratio) to the
//! seed's naive filter/clone/sort reference (`testutil::naive`) on
//! randomized hybrid configs, and the per-device ranges must exactly
//! partition the span set. Equality below is `==` on f64, deliberately:
//! the refactor reorders storage, not arithmetic.

use distsim::cluster::ClusterSpec;
use distsim::config::RunConfig;
use distsim::engine::{ExecScratch, GroundTruth};
use distsim::exp::eval_cfg;
use distsim::metrics;
use distsim::schedule::Phase;
use distsim::strategy::Strategy;
use distsim::testutil::{self, naive};
use distsim::timeline::{analysis, Span, SpanKind, Tag, Timeline};

/// Assert every indexed query equals its naive reference on `t`.
fn assert_indexed_matches_naive(t: &Timeline, ctx: &str) {
    assert_eq!(t.batch_time_us(), naive::batch_time_us(t), "{ctx}: batch time");
    assert_eq!(t.start_us(), naive::start_us(t), "{ctx}: start");
    for d in 0..t.n_devices {
        assert_eq!(
            t.device_spans(d),
            naive::device_spans(t, d).as_slice(),
            "{ctx}: device {d} spans"
        );
        assert_eq!(
            t.device_comp_spans(d),
            naive::device_comp_spans(t, d).as_slice(),
            "{ctx}: device {d} comp spans"
        );
        assert_eq!(t.busy_us(d), naive::busy_us(t, d), "{ctx}: device {d} busy");
    }
    assert_eq!(
        metrics::stage_timestamps(t),
        naive::stage_timestamps(t),
        "{ctx}: stage timestamps"
    );
    assert_eq!(
        analysis::bubble_ratio(t),
        naive::bubble_ratio(t),
        "{ctx}: bubble ratio"
    );
}

#[test]
fn golden_metrics_match_naive_reference_on_random_hybrids() {
    testutil::check("timeline-golden", 8, |rng| {
        let mp = 1 << rng.below(2); // 1,2
        let pp = 1 << rng.below(3); // 1,2,4
        let dp = 1 << rng.below(2); // 1,2
        let sched = *testutil::pick(rng, &["gpipe", "dapple"]);
        let mut cfg = RunConfig::new(
            "bert-large",
            Strategy::new(mp, pp, dp),
            ClusterSpec::a40_cluster(4, 4),
        );
        cfg.schedule = sched.to_string();
        cfg.micro_batches = 1 + rng.below(4) as usize;
        cfg.profile_iters = 3;
        cfg.seed = rng.next_u64();
        let run = eval_cfg(&cfg).unwrap();
        let actual = run.gt.run_iteration(0);
        let ctx = format!("{mp}M{pp}P{dp}D {sched}");

        assert_indexed_matches_naive(&actual, &format!("{ctx} actual"));
        assert_indexed_matches_naive(&run.predicted, &format!("{ctx} predicted"));

        // the cross-timeline metrics, indexed vs seed-semantics reference
        assert_eq!(
            metrics::per_gpu_activity_error_pct(&run.predicted, &actual),
            naive::per_gpu_activity_error_pct(&run.predicted, &actual),
            "{ctx}: per-GPU activity error"
        );
    });
}

#[test]
fn per_device_ranges_exactly_partition_randomized_span_sets() {
    testutil::check("range-partition", 50, |rng| {
        let n = 1 + rng.below(6) as usize;
        let count = rng.below(64) as usize;
        let mut pushed = Vec::with_capacity(count);
        let mut t = Timeline::new(n);
        for i in 0..count {
            let device = rng.below(n as u64) as usize;
            let start = rng.f64() * 1000.0;
            let span = Span {
                device,
                start,
                end: start + rng.f64() * 50.0,
                tag: Tag {
                    stage: 0,
                    mb: i as u32, // unique id so the multiset check is exact
                    phase: Phase::Fwd,
                    layer: 0,
                    kind: if rng.f64() < 0.5 { SpanKind::Comp } else { SpanKind::P2p },
                    idx: 0,
                },
            };
            pushed.push(span);
            t.push(span);
        }
        t.finalize();

        // the ranges cover every span exactly once...
        let total: usize = (0..n).map(|d| t.device_spans(d).len()).sum();
        assert_eq!(total, t.len());
        assert_eq!(t.len(), pushed.len());
        // ...each range holds only its own device, in start order...
        for d in 0..n {
            let lane = t.device_spans(d);
            assert!(lane.iter().all(|s| s.device == d), "foreign span in lane {d}");
            assert!(
                lane.windows(2).all(|w| w[0].start <= w[1].start),
                "lane {d} unsorted"
            );
        }
        // ...and their union is the pushed multiset (mb is unique per span)
        let mut got: Vec<Span> = (0..n).flat_map(|d| t.device_spans(d).to_vec()).collect();
        got.sort_by_key(|s| s.tag.mb);
        let mut want = pushed.clone();
        want.sort_by_key(|s| s.tag.mb);
        assert_eq!(got, want);
    });
}

#[test]
fn scratch_path_is_bit_identical_to_fresh_path_over_iterations() {
    let cfg = RunConfig::new(
        "bert-large",
        Strategy::new(2, 2, 2),
        ClusterSpec::a40_cluster(4, 4),
    );
    let gt = GroundTruth::prepare(&cfg).unwrap();
    let mut scratch = ExecScratch::new();
    for iter in 0..5u64 {
        let fresh = gt.run_iteration(iter);
        let reused = gt.run_iteration_with_scratch(iter, &mut scratch);
        assert_eq!(fresh.len(), reused.len(), "iter {iter}");
        assert_eq!(fresh.spans(), reused.spans(), "iter {iter}");
        assert_eq!(fresh.batch_time_us(), reused.batch_time_us(), "iter {iter}");
        scratch.recycle(reused);
    }
}

#[test]
fn scratch_survives_program_shape_changes() {
    // one scratch reused across different (mp, pp, dp) programs — the
    // sweep's usage pattern — must still match the fresh path exactly
    let mut scratch = ExecScratch::new();
    for (mp, pp, dp) in [(2, 2, 2), (1, 4, 2), (4, 1, 1), (1, 1, 4), (2, 4, 2)] {
        let cfg = RunConfig::new(
            "bert-large",
            Strategy::new(mp, pp, dp),
            ClusterSpec::a40_cluster(4, 4),
        );
        let gt = GroundTruth::prepare(&cfg).unwrap();
        let fresh = gt.run_iteration(0);
        let reused = gt.run_iteration_with_scratch(0, &mut scratch);
        assert_eq!(fresh.spans(), reused.spans(), "{mp}M{pp}P{dp}D");
        scratch.recycle(reused);
    }
}
