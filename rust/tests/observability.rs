//! Integration tests for the telemetry subsystem (ISSUE 8): the
//! out-of-band timing rule (telemetry on vs off must not move a single
//! response byte), the `metrics` op's reconciliation with `stats`, the
//! opt-in `trace` response block, structured `unavailable` shed fields,
//! and `--trace-dir` Chrome-trace files.

use std::io::Cursor;
use std::path::PathBuf;

use distsim::config::Json;
use distsim::service::{serve_ndjson, ServeOpts, ServeSummary};
use distsim::telemetry::{LogLevel, ServiceMetrics, TRACE_PHASES, TRACE_QUANTUM_US};

/// Run an NDJSON session in-process and return its response lines.
fn run_lines(input: &str, opts: &ServeOpts) -> (Vec<String>, ServeSummary) {
    let mut out: Vec<u8> = Vec::new();
    let summary = serve_ndjson(Cursor::new(input.to_string()), &mut out, opts);
    let text = String::from_utf8(out).expect("responses are utf-8");
    (text.lines().map(str::to_string).collect(), summary)
}

fn opts_with_workers(workers: usize) -> ServeOpts {
    ServeOpts {
        workers,
        ..ServeOpts::default()
    }
}

/// A small, fast sweep request: 6 candidates on 4 devices.
fn small_sweep(id: &str, global_batch: usize) -> String {
    format!(
        r#"{{"id":"{id}","op":"sweep","model":"bert-large","cluster":{{"preset":"a40","nodes":1,"gpus_per_node":4}},"sweep":{{"global_batch":{global_batch},"profile_iters":1}}}}"#
    )
}

/// Same sweep with extra `sweep` fields spliced in (e.g. `"trace":true`).
fn sweep_with(id: &str, global_batch: usize, extra: &str) -> String {
    format!(
        r#"{{"id":"{id}","op":"sweep","model":"bert-large","cluster":{{"preset":"a40","nodes":1,"gpus_per_node":4}},"sweep":{{"global_batch":{global_batch},"profile_iters":1,{extra}}}}}"#
    )
}

fn parse(line: &str) -> Json {
    Json::parse(line).unwrap_or_else(|e| panic!("unparseable response '{line}': {e}"))
}

fn result_field<'a>(j: &'a Json, k: &str) -> &'a Json {
    j.get("result")
        .unwrap_or_else(|| panic!("no result in {j}"))
        .get(k)
        .unwrap_or_else(|| panic!("no result.{k} in {j}"))
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "distsim_observability_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn counter(metrics: &Json, name: &str) -> u64 {
    result_field(metrics, "metrics")
        .get("counters")
        .and_then(|c| c.get(name))
        .and_then(Json::as_u64)
        .unwrap_or_else(|| panic!("no counter {name} in {metrics}"))
}

fn gauge(metrics: &Json, name: &str) -> u64 {
    result_field(metrics, "metrics")
        .get("gauges")
        .and_then(|g| g.get(name))
        .and_then(Json::as_u64)
        .unwrap_or_else(|| panic!("no gauge {name} in {metrics}"))
}

/// The tentpole's hard constraint: a fully instrumented daemon
/// (`--trace-dir` tracing every sweep, debug logging) must produce the
/// exact same response bytes as a bare one — all timing is out-of-band
/// (DESIGN.md §9), and the `trace` block stays gated on `sweep.trace`.
#[test]
fn telemetry_on_and_off_response_streams_are_byte_identical() {
    let input = [
        small_sweep("a", 4),
        r#"{"id":"p","op":"ping"}"#.to_string(),
        small_sweep("b", 8),
        small_sweep("a2", 4), // repeat: cache-hit accounting included
    ]
    .join("\n");
    let dir = fresh_dir("identity");
    let (off, _) = run_lines(&input, &opts_with_workers(2));
    let (on, _) = run_lines(
        &input,
        &ServeOpts {
            workers: 2,
            trace_dir: Some(dir.clone()),
            log_level: LogLevel::Debug,
            ..ServeOpts::default()
        },
    );
    assert_eq!(off, on, "telemetry moved a response byte");
    // tracing really was live on the instrumented run
    let n_files = std::fs::read_dir(&dir).expect("trace dir exists").count();
    assert_eq!(n_files, 3, "one Chrome-trace file per completed sweep");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `sweep.trace: true` adds exactly one `trace` key to the result — the
/// rest of the payload is the byte-identical deterministic sweep. The
/// block itself is quantized and flagged non-deterministic.
#[test]
fn trace_block_is_opt_in_quantized_and_additive() {
    let (plain_lines, _) = run_lines(&small_sweep("t", 4), &opts_with_workers(1));
    let (traced_lines, _) = run_lines(
        &sweep_with("t", 4, r#""trace":true"#),
        &opts_with_workers(1),
    );
    let plain = parse(&plain_lines[0]);
    let traced = parse(&traced_lines[0]);

    let plain_result = plain.get("result").unwrap().as_obj().unwrap();
    let traced_result = traced.get("result").unwrap().as_obj().unwrap();
    assert_eq!(traced_result.len(), plain_result.len() + 1);
    for (k, v) in plain_result {
        assert_eq!(
            traced_result.get(k).map(|t| t.to_string()),
            Some(v.to_string()),
            "deterministic field {k} changed under tracing"
        );
    }

    let block = traced_result.get("trace").expect("trace block present");
    assert_eq!(
        block.get("deterministic").and_then(Json::as_bool),
        Some(false)
    );
    assert_eq!(
        block.get("quantum_us").and_then(Json::as_u64),
        Some(TRACE_QUANTUM_US)
    );
    let spans = block.get("spans").and_then(Json::as_arr).unwrap();
    let names: Vec<&str> = spans
        .iter()
        .map(|s| s.get("name").and_then(Json::as_str).unwrap())
        .collect();
    for phase in ["queue", "sweep", "source", "evaluate"] {
        assert!(names.contains(&phase), "missing {phase} span: {names:?}");
    }
    for name in &names {
        assert!(TRACE_PHASES.contains(name), "undocumented phase {name}");
    }
    for s in spans {
        let start = s.get("start_us").and_then(Json::as_u64).unwrap();
        let dur = s.get("dur_us").and_then(Json::as_u64).unwrap();
        assert_eq!(start % TRACE_QUANTUM_US, 0, "unquantized start in {s}");
        assert_eq!(dur % TRACE_QUANTUM_US, 0, "unquantized dur in {s}");
    }
}

/// The `metrics` op reconciles exactly with `stats` (same registry, same
/// delivery point), counts every delivered request including itself, and
/// agrees with the per-response cache accounting.
#[test]
fn metrics_op_reconciles_with_stats_and_is_monotonic() {
    let input = [
        small_sweep("a", 4),
        sweep_with(
            "scn",
            4,
            r#""scenario":{"stragglers":[{"device":0,"factor":1.5}]}"#,
        ),
        small_sweep("a2", 4), // repeat: guaranteed cache hits
        r#"{"id":"st","op":"stats"}"#.to_string(),
        r#"{"id":"m1","op":"metrics"}"#.to_string(),
        r#"{"id":"m2","op":"metrics"}"#.to_string(),
    ]
    .join("\n");
    let (lines, summary) = run_lines(&input, &opts_with_workers(2));
    assert_eq!(lines.len(), 6);
    assert_eq!(summary.sweeps, 3);

    let hits: u64 = lines[..3]
        .iter()
        .map(|l| {
            result_field(&parse(l), "cache")
                .get("hits")
                .and_then(Json::as_u64)
                .unwrap()
        })
        .sum();
    assert!(hits > 0, "the repeated sweep must hit the cache");

    let stats = parse(&lines[3]);
    let m1 = parse(&lines[4]);
    let m2 = parse(&lines[5]);
    assert_eq!(
        result_field(&m1, "deterministic").as_bool(),
        Some(false),
        "the metrics payload is diagnostic, like stats"
    );

    // exact reconciliation with the stats op
    let scenario = result_field(&stats, "scenario");
    assert_eq!(
        counter(&m1, "scenario_sweeps_total"),
        scenario.get("sweeps").and_then(Json::as_u64).unwrap()
    );
    assert_eq!(
        counter(&m1, "scenario_episodes_total"),
        scenario.get("episodes").and_then(Json::as_u64).unwrap()
    );
    let caches = result_field(&stats, "caches").as_arr().unwrap();
    assert_eq!(gauge(&m1, "caches"), caches.len() as u64);
    let events: u64 = caches
        .iter()
        .map(|c| c.get("events").and_then(Json::as_u64).unwrap())
        .sum();
    assert_eq!(gauge(&m1, "cache_events"), events);

    // request/sweep/cache counters agree with the session itself
    assert_eq!(counter(&m1, "sweeps_total"), 3);
    assert_eq!(counter(&m1, "cache_hits_total"), hits);
    assert_eq!(
        counter(&m1, "requests_total"),
        5,
        "3 sweeps + stats + this metrics response"
    );
    assert_eq!(counter(&m2, "requests_total"), 6, "monotonic across calls");

    // both exposition forms carry the same values
    let prom = result_field(&m1, "prometheus").as_str().unwrap();
    for (name, value) in [
        ("sweeps_total", counter(&m1, "sweeps_total")),
        ("cache_hits_total", hits),
        ("requests_total", 5),
    ] {
        let line = format!("distsim_{name} {value}");
        assert!(
            prom.lines().any(|l| l == line),
            "prometheus text lacks '{line}':\n{prom}"
        );
    }
    // the wall-clock histograms saw every executed sweep
    let wait = result_field(&m1, "metrics")
        .get("histograms")
        .and_then(|h| h.get("queue_wait_us"))
        .expect("queue_wait_us histogram");
    assert_eq!(wait.get("count").and_then(Json::as_u64), Some(3));

    // every name the registry declares appears in both forms
    let m = ServiceMetrics::new();
    let json_text = result_field(&m1, "metrics").to_string();
    for name in m.names() {
        assert!(json_text.contains(&format!("\"{name}\"")), "json lacks {name}");
        assert!(prom.contains(&format!("distsim_{name}")), "prom lacks {name}");
    }
}

/// Queue-full sheds carry machine-readable `depth` / `max_queue` fields
/// next to the prose message (FORMATS.md §1.6).
#[test]
fn queue_full_shed_carries_structured_depth_fields() {
    let input = [
        small_sweep("s0", 4),
        small_sweep("s1", 4),
        small_sweep("s2", 4),
        small_sweep("s3", 4),
    ]
    .join("\n");
    let opts = ServeOpts {
        workers: 1,
        max_queue: 1,
        ..ServeOpts::default()
    };
    let (lines, _) = run_lines(&input, &opts);
    let sheds: Vec<Json> = lines
        .iter()
        .map(|l| parse(l))
        .filter(|j| j.get("ok").and_then(Json::as_bool) == Some(false))
        .collect();
    assert!(!sheds.is_empty(), "queue bound 1 with a 4-sweep burst must shed");
    for j in &sheds {
        let err = j.get("error").unwrap();
        assert_eq!(err.get("kind").and_then(Json::as_str), Some("unavailable"));
        assert_eq!(err.get("max_queue").and_then(Json::as_u64), Some(1), "{j}");
        assert!(
            err.get("depth").and_then(Json::as_u64).unwrap() >= 1,
            "{j}"
        );
        // the prose message is still there for humans
        assert!(err
            .get("message")
            .and_then(Json::as_str)
            .unwrap()
            .contains("queue is full"));
    }
}

/// `--trace-dir` writes one valid Chrome-trace JSON file per completed
/// sweep, named `trace-conn<conn>-seq<seq>.json`, with the documented
/// phase names — including the engine's `bound` stage when pruning and
/// the `write` span the response block can never contain.
#[test]
fn trace_dir_files_are_valid_chrome_traces_with_expected_phases() {
    let dir = fresh_dir("chrome");
    let input = [
        sweep_with("pruned", 8, r#""prune":true"#),
        r#"{"id":"p","op":"ping"}"#.to_string(), // control ops are never traced
        small_sweep("plain", 4),
    ]
    .join("\n");
    let opts = ServeOpts {
        workers: 2,
        trace_dir: Some(dir.clone()),
        log_level: LogLevel::Error,
        ..ServeOpts::default()
    };
    let (lines, summary) = run_lines(&input, &opts);
    assert_eq!((lines.len(), summary.sweeps), (3, 2));

    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .expect("trace dir")
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    names.sort();
    assert_eq!(
        names,
        vec!["trace-conn0-seq0.json", "trace-conn0-seq2.json"],
        "one file per sweep, keyed by connection and per-conn seq"
    );

    for (file, expect_bound) in [("trace-conn0-seq0.json", true), ("trace-conn0-seq2.json", false)]
    {
        let text = std::fs::read_to_string(dir.join(file)).unwrap();
        let doc = Json::parse(&text).unwrap_or_else(|e| panic!("{file} invalid: {e}"));
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let phases: Vec<&str> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .map(|e| e.get("name").and_then(Json::as_str).unwrap())
            .collect();
        for phase in ["queue", "sweep", "source", "evaluate", "write"] {
            assert!(phases.contains(&phase), "{file} lacks {phase}: {phases:?}");
        }
        assert_eq!(
            phases.contains(&"bound"),
            expect_bound,
            "only the pruned sweep runs the bound stage: {file} {phases:?}"
        );
        // the metadata track is labeled with the request id
        let meta = events
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .expect("thread_name metadata");
        let label = meta
            .get("args")
            .and_then(|a| a.get("name"))
            .and_then(Json::as_str)
            .unwrap();
        assert!(label.starts_with("request "), "{label}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
