//! Integration tests for the parallel strategy-sweep engine: the
//! determinism contract, the profile cache's dedup accounting, pruning
//! soundness against an exhaustive sweep, and seed-path equivalence.

use distsim::cluster::ClusterSpec;
use distsim::cost::CostModel;
use distsim::model::zoo;
use distsim::profile::ProfileReport;
use distsim::search::{
    evaluate_candidate, grid, grid_search, SearchEngine, SweepConfig, SweepReport,
};

fn run_sweep(cfg: SweepConfig) -> SweepReport {
    let model = zoo::bert_ex_large();
    let cluster = ClusterSpec::a10_cluster(4, 4);
    let cost = CostModel::default();
    SearchEngine::new(&model, &cluster, &cost, cfg).sweep()
}

#[test]
fn sweep_is_deterministic_across_thread_counts() {
    // same seed + grid => identical deterministic payload on 1, 2 and 8
    // worker threads (jitter on, to exercise the noisy profiling path)
    let cfg = |threads| SweepConfig {
        threads,
        jitter_sigma: 0.02,
        profile_iters: 2,
        ..SweepConfig::default()
    };
    let one = run_sweep(cfg(1));
    for threads in [2, 8] {
        let many = run_sweep(cfg(threads));
        assert_eq!(one.candidates, many.candidates, "{threads} threads");
        assert_eq!(one.profile, many.profile, "{threads} threads");
        assert_eq!(one.cache, many.cache, "{threads} threads");
    }
}

#[test]
fn sweep_is_deterministic_with_pruning_and_widened_space() {
    let cfg = |threads| SweepConfig {
        threads,
        prune: true,
        widened: true,
        micro_batch_axis: true,
        ..SweepConfig::default()
    };
    let one = run_sweep(cfg(1));
    let many = run_sweep(cfg(8));
    assert_eq!(one.candidates, many.candidates);
    assert_eq!(one.profile, many.profile);
    assert_eq!(
        one.pruned_count(),
        many.pruned_count(),
        "pruning must not depend on thread count"
    );
}

#[test]
fn memory_constrained_sweep_is_deterministic_across_thread_counts() {
    // capacity + both memory axes: the feasibility stage prunes, the
    // survivors replicate over (recompute, zero) — and the deterministic
    // payload must still be identical on 1, 2 and 8 worker threads
    let model = zoo::bert_large();
    let cluster = ClusterSpec::a40_cluster(2, 2).with_uniform_capacity(3_000_000_000);
    let cost = CostModel::default();
    let cfg = |threads| SweepConfig {
        threads,
        jitter_sigma: 0.02,
        profile_iters: 2,
        micro_batch_axis: true,
        recompute_axis: true,
        zero_axis: true,
        ..SweepConfig::default()
    };
    let one = SearchEngine::new(&model, &cluster, &cost, cfg(1)).sweep();
    assert!(one.pruning.memory_pruned > 0, "capacity must bind");
    assert!(one.best().is_some(), "something must still fit");
    for threads in [2, 8] {
        let many = SearchEngine::new(&model, &cluster, &cost, cfg(threads)).sweep();
        assert_eq!(one.candidates, many.candidates, "{threads} threads");
        assert_eq!(one.profile, many.profile, "{threads} threads");
        assert_eq!(one.cache, many.cache, "{threads} threads");
        assert_eq!(one.pruning, many.pruning, "{threads} threads");
    }
}

#[test]
fn cache_dedups_profiling_across_candidates() {
    let cached = run_sweep(SweepConfig::default());
    let uncached = run_sweep(SweepConfig {
        use_cache: false,
        ..SweepConfig::default()
    });

    // identical values either way: a hit returns exactly what a fresh
    // measurement would
    assert_eq!(cached.candidates, uncached.candidates);

    // but the cached sweep measures each unique event once
    assert!(cached.cache.hits > 0, "15 candidates must share events");
    assert_eq!(cached.cache.misses, cached.profile.events_profiled);
    assert_eq!(cached.profile.cache_hits, cached.cache.hits);
    assert!(
        cached.profile.events_profiled < uncached.profile.events_profiled,
        "dedup: {} unique vs {} per-candidate measurements",
        cached.profile.events_profiled,
        uncached.profile.events_profiled
    );
    assert!(cached.profile.gpu_seconds < uncached.profile.gpu_seconds);
}

#[test]
fn pruned_candidates_are_never_the_argmax() {
    // exhaustively evaluate a small grid, then re-run with pruning: the
    // pruning pass must only ever discard non-winners, and the reported
    // best must not change. BERT-exLarge's grid has a known 3-15x spread
    // (see the search unit tests), so provably-losing candidates exist.
    let model = zoo::bert_ex_large();
    let cluster = ClusterSpec::a10_cluster(4, 4);
    let cost = CostModel::default();
    let base = SweepConfig::default();

    let exhaustive = SearchEngine::new(&model, &cluster, &cost, base.clone()).sweep();
    let pruned = SearchEngine::new(
        &model,
        &cluster,
        &cost,
        SweepConfig {
            prune: true,
            ..base
        },
    )
    .sweep();

    let true_best = exhaustive.best().expect("exhaustive sweep has a winner");
    assert!(
        pruned.pruned_count() > 0,
        "grid should contain provably-losing candidates"
    );
    for c in pruned.candidates.iter().filter(|c| c.pruned) {
        assert_ne!(
            c.strategy, true_best.strategy,
            "pruning discarded the true argmax {}",
            true_best.strategy
        );
    }
    let pruned_best = pruned.best().expect("pruned sweep still has a winner");
    assert_eq!(pruned_best.strategy, true_best.strategy);
    assert_eq!(pruned_best.throughput, true_best.throughput);
}

#[test]
fn engine_matches_the_legacy_serial_seed_path() {
    // grid_search is now engine-backed; its values must equal a manual
    // serial loop over the original evaluate_candidate free function.
    let model = zoo::bert_ex_large();
    let cluster = ClusterSpec::a10_cluster(4, 4);
    let cost = CostModel::default();

    let report = grid_search(&model, &cluster, &cost, 16, 0.02, 2);

    let mut legacy_profile = ProfileReport::default();
    let legacy: Vec<_> = grid(16)
        .iter()
        .map(|s| {
            evaluate_candidate(&model, s, &cluster, &cost, 16, 0.02, 2, &mut legacy_profile)
        })
        .collect();

    assert_eq!(report.candidates.len(), legacy.len());
    for (a, b) in report.candidates.iter().zip(&legacy) {
        assert_eq!(a.strategy, b.strategy);
        assert_eq!(a.reachable, b.reachable);
        assert_eq!(a.micro_batches, b.micro_batches);
        assert_eq!(
            a.throughput, b.throughput,
            "{}: engine and seed path disagree",
            a.strategy
        );
    }
    // the engine's deduped profiling must cost no more than the legacy sum
    assert!(report.profile.gpu_seconds <= legacy_profile.gpu_seconds);
}

#[test]
fn widened_sweep_handles_non_pow2_device_counts() {
    // 3 nodes x 4 GPUs = 12 devices: the widened space includes 3-way
    // splits the pow2 grid cannot express, and the sweep stays total.
    let model = zoo::bert_large();
    let cluster = ClusterSpec::a40_cluster(3, 4);
    let cost = CostModel::default();
    let cfg = SweepConfig {
        widened: true,
        global_batch: 12,
        ..SweepConfig::default()
    };
    let rep = SearchEngine::new(&model, &cluster, &cost, cfg).sweep();
    assert!(rep
        .candidates
        .iter()
        .any(|c| c.strategy.pp == 3 && c.evaluated()));
    // mp=3 does not divide bert-large's 16 heads -> invalid, not a crash
    assert!(rep
        .candidates
        .iter()
        .filter(|c| c.strategy.mp == 3)
        .all(|c| !c.reachable));
    assert!(rep.best().is_some());
}
