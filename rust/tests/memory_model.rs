//! Property tests pinning the per-rank memory model (ISSUE 9): peak
//! residency monotonicity along the strategy axes, the ZeRO-1 and
//! recompute trade-offs, and feasibility-pruning soundness — every
//! engine verdict checked against the naive rescan reference in
//! `testutil::naive`.
//!
//! pp monotonicity only holds when pp divides the layer count (uneven
//! splits concentrate layers on one stage), so every sampled pp here is
//! a divisor of BERT-large's 24 layers.

use distsim::cluster::ClusterSpec;
use distsim::cost::CostModel;
use distsim::memory::{self, Recompute};
use distsim::model::zoo;
use distsim::partition::partition_opts;
use distsim::schedule::SchedKind;
use distsim::search::{SearchEngine, SweepConfig};
use distsim::strategy::Strategy;
use distsim::testutil::{check, naive, pick};

/// Valid BERT-large points on a 16-device fleet: mp divides 16 heads,
/// pp divides 24 layers, world size <= 16.
const STRATEGIES: [(usize, usize, usize); 10] = [
    (1, 1, 1),
    (1, 1, 2),
    (2, 1, 1),
    (1, 2, 1),
    (2, 2, 2),
    (1, 2, 4),
    (4, 2, 2),
    (2, 4, 2),
    (1, 4, 4),
    (2, 2, 4),
];

#[allow(clippy::too_many_arguments)]
fn peak(
    mp: usize,
    pp: usize,
    dp: usize,
    mbs: usize,
    micro_batches: usize,
    sched: SchedKind,
    rc: Recompute,
    zero: u8,
    cluster: &ClusterSpec,
) -> u64 {
    let model = zoo::bert_large();
    let s = Strategy::new(mp, pp, dp);
    let part = partition_opts(&model, &s, cluster, mbs, rc, zero);
    let sch = sched.build(pp, micro_batches);
    memory::assess(&part, &sch, cluster, rc, zero).peak_bytes
}

#[test]
fn peak_bytes_monotone_in_mp_pp_and_mbs() {
    let cluster = ClusterSpec::a40_cluster(4, 4);
    check("memory-monotonicity", 64, |rng| {
        let sched = *pick(rng, &[SchedKind::Dapple, SchedKind::GPipe]);
        let m = *pick(rng, &[1usize, 2, 4, 8]);
        let mbs = *pick(rng, &[1usize, 2, 4]);
        let dp = *pick(rng, &[1usize, 2]);
        let rc = *pick(rng, &[Recompute::None, Recompute::Full]);
        let zero = rng.below(2) as u8;
        // doubling mp at fixed (pp, dp): never more resident bytes
        for pp in [1usize, 2] {
            let p1 = peak(1, pp, dp, mbs, m, sched, rc, zero, &cluster);
            let p2 = peak(2, pp, dp, mbs, m, sched, rc, zero, &cluster);
            let p4 = peak(4, pp, dp, mbs, m, sched, rc, zero, &cluster);
            assert!(
                p4 <= p2 && p2 <= p1,
                "mp not monotone: {p1} -> {p2} -> {p4} (pp={pp} dp={dp} mbs={mbs} m={m} {sched} {rc} z{zero})"
            );
        }
        // deepening the pipeline over divisor pp at fixed (mp, dp)
        for mp in [1usize, 2] {
            let p1 = peak(mp, 1, dp, mbs, m, sched, rc, zero, &cluster);
            let p2 = peak(mp, 2, dp, mbs, m, sched, rc, zero, &cluster);
            let p4 = peak(mp, 4, dp, mbs, m, sched, rc, zero, &cluster);
            assert!(
                p4 <= p2 && p2 <= p1,
                "pp not monotone: {p1} -> {p2} -> {p4} (mp={mp} dp={dp} mbs={mbs} m={m} {sched} {rc} z{zero})"
            );
        }
        // growing the micro-batch at a fixed point: never fewer bytes
        let (mp, pp, dp) = *pick(rng, &STRATEGIES);
        let b1 = peak(mp, pp, dp, 1, m, sched, rc, zero, &cluster);
        let b2 = peak(mp, pp, dp, 2, m, sched, rc, zero, &cluster);
        let b4 = peak(mp, pp, dp, 4, m, sched, rc, zero, &cluster);
        assert!(
            b1 <= b2 && b2 <= b4,
            "mbs not monotone: {b1} -> {b2} -> {b4} ({mp}M{pp}P{dp}D m={m} {sched} {rc} z{zero})"
        );
    });
}

#[test]
fn zero_one_shrinks_optimizer_state_iff_dp_exceeds_one() {
    let cluster = ClusterSpec::a40_cluster(4, 4);
    let model = zoo::bert_large();
    check("zero-stage", 48, |rng| {
        let (mp, pp, dp) = *pick(rng, &STRATEGIES);
        let mbs = *pick(rng, &[1usize, 2, 4]);
        let m = *pick(rng, &[1usize, 2, 4]);
        let s = Strategy::new(mp, pp, dp);
        let sch = SchedKind::Dapple.build(pp, m);
        for stage in 0..pp {
            let base = {
                let part = partition_opts(&model, &s, &cluster, mbs, Recompute::None, 0);
                memory::stage_bytes(&part, &sch, stage, Recompute::None, 0)
            };
            let zero = {
                let part = partition_opts(&model, &s, &cluster, mbs, Recompute::None, 1);
                memory::stage_bytes(&part, &sch, stage, Recompute::None, 1)
            };
            // only the optimizer family moves, and only when dp > 1
            assert_eq!(zero.weights, base.weights, "stage {stage}");
            assert_eq!(zero.grads, base.grads, "stage {stage}");
            assert_eq!(zero.activations, base.activations, "stage {stage}");
            if dp > 1 {
                assert!(
                    zero.optimizer < base.optimizer,
                    "{mp}M{pp}P{dp}D stage {stage}: ZeRO-1 must strictly shrink \
                     optimizer state ({} !< {})",
                    zero.optimizer,
                    base.optimizer
                );
                assert_eq!(zero.optimizer, base.optimizer.div_ceil(dp as u64));
            } else {
                assert_eq!(zero.optimizer, base.optimizer, "dp=1 is a no-op");
            }
        }
    });
}

#[test]
fn recompute_full_strictly_shrinks_activations() {
    let cluster = ClusterSpec::a40_cluster(4, 4);
    let model = zoo::bert_large();
    check("recompute-bytes", 48, |rng| {
        let (mp, pp, dp) = *pick(rng, &STRATEGIES);
        let mbs = *pick(rng, &[1usize, 2, 4]);
        let m = *pick(rng, &[1usize, 2, 4]);
        let s = Strategy::new(mp, pp, dp);
        let sch = SchedKind::Dapple.build(pp, m);
        let base_part = partition_opts(&model, &s, &cluster, mbs, Recompute::None, 0);
        let rc_part = partition_opts(&model, &s, &cluster, mbs, Recompute::Full, 0);
        for stage in 0..pp {
            let base = memory::stage_bytes(&base_part, &sch, stage, Recompute::None, 0);
            let rc = memory::stage_bytes(&rc_part, &sch, stage, Recompute::Full, 0);
            // bert-large holds >= 6 layers per stage at pp <= 4, so the
            // stage-boundary-only residency is a strict reduction
            assert!(
                rc.activations < base.activations,
                "{mp}M{pp}P{dp}D stage {stage}: {} !< {}",
                rc.activations,
                base.activations
            );
            assert_eq!(rc.weights, base.weights);
            assert_eq!(rc.grads, base.grads);
            assert_eq!(rc.optimizer, base.optimizer);
        }
    });
}

#[test]
fn recompute_full_never_beats_its_baseline_twin() {
    // memory is the only thing recompute buys: the merged backward event
    // carries the forward's flops and bytes on top of its own, and the
    // deterministic roofline is monotone in both — so the full-recompute
    // twin of any evaluated point can never be faster
    let model = zoo::bert_large();
    let cluster = ClusterSpec::a40_cluster(2, 2);
    let cost = CostModel::default();
    let cfg = SweepConfig {
        recompute_axis: true,
        memory: true,
        micro_batch_axis: true,
        ..SweepConfig::default()
    };
    let report = SearchEngine::new(&model, &cluster, &cost, cfg).sweep();
    let mut checked = 0usize;
    for f in report
        .candidates
        .iter()
        .filter(|c| c.recompute == Recompute::Full && c.evaluated())
    {
        let base = report
            .candidates
            .iter()
            .find(|c| {
                c.recompute == Recompute::None
                    && c.zero_stage == f.zero_stage
                    && c.strategy == f.strategy
                    && c.micro_batch_size == f.micro_batch_size
                    && c.micro_batches == f.micro_batches
                    && c.schedule == f.schedule
                    && c.placement == f.placement
            })
            .expect("every full point has a baseline twin");
        assert!(
            f.throughput <= base.throughput,
            "{}: recompute sped up {} -> {}",
            f.strategy,
            base.throughput,
            f.throughput
        );
        assert!(
            f.peak_bytes < base.peak_bytes,
            "{}: recompute must shrink the peak",
            f.strategy
        );
        checked += 1;
    }
    assert!(checked > 0, "axis produced no evaluated full points");
}

#[test]
fn assess_matches_the_naive_reference() {
    let model = zoo::bert_large();
    let cluster = ClusterSpec::a40_cluster(4, 4);
    check("memory-differential", 48, |rng| {
        let (mp, pp, dp) = *pick(rng, &STRATEGIES);
        let mbs = *pick(rng, &[1usize, 2, 4]);
        let m = *pick(rng, &[1usize, 2, 4, 8]);
        let sched = *pick(rng, &[SchedKind::Dapple, SchedKind::GPipe]);
        let rc = *pick(rng, &[Recompute::None, Recompute::Full]);
        let zero = rng.below(2) as u8;
        let s = Strategy::new(mp, pp, dp);
        let part = partition_opts(&model, &s, &cluster, mbs, rc, zero);
        let sch = sched.build(pp, m);
        let naive_peak = (0..s.world_size())
            .map(|r| naive::rank_peak_bytes(&part, &sch, r, rc, zero))
            .max()
            .unwrap();
        let rep = memory::assess(&part, &sch, &cluster, rc, zero);
        assert_eq!(rep.peak_bytes, naive_peak, "{mp}M{pp}P{dp}D {sched} {rc} z{zero}");
        // capacities straddling the peak, plus a random one below it:
        // fits and the exact oom rank set must agree with the rescan
        for cap in [naive_peak - 1, naive_peak, 1 + rng.below(naive_peak)] {
            let capped = cluster.with_uniform_capacity(cap);
            let rep = memory::assess(&part, &sch, &capped, rc, zero);
            let (fits, oom) = naive::memory_feasible(&part, &sch, &capped, rc, zero);
            assert_eq!(rep.fits, fits, "cap {cap}");
            assert_eq!(rep.oom_ranks, oom, "cap {cap}");
        }
    });
}

#[test]
fn engine_feasibility_verdicts_match_the_naive_reference() {
    // the staged pipeline's oom placeholders, differentially: every
    // candidate the memory stage priced must carry exactly the verdict
    // the naive per-rank rescan reaches from the candidate's own fields
    let model = zoo::bert_large();
    let cluster = ClusterSpec::a40_cluster(2, 2).with_uniform_capacity(3_000_000_000);
    let cost = CostModel::default();
    let cfg = SweepConfig {
        micro_batch_axis: true,
        recompute_axis: true,
        zero_axis: true,
        ..SweepConfig::default()
    };
    let report = SearchEngine::new(&model, &cluster, &cost, cfg).sweep();
    assert!(report.pruning.memory_pruned > 0, "capacity must bind");
    let mut priced = 0usize;
    for c in report.candidates.iter().filter(|c| c.peak_bytes > 0) {
        let part = partition_opts(
            &model,
            &c.strategy,
            &cluster,
            c.micro_batch_size,
            c.recompute,
            c.zero_stage,
        );
        let sch = c.schedule.build(c.strategy.pp, c.micro_batches);
        let naive_peak = (0..c.strategy.world_size())
            .map(|r| naive::rank_peak_bytes(&part, &sch, r, c.recompute, c.zero_stage))
            .max()
            .unwrap();
        let (fits, _) = naive::memory_feasible(&part, &sch, &cluster, c.recompute, c.zero_stage);
        assert_eq!(c.peak_bytes, naive_peak, "{}", c.strategy);
        assert_eq!(c.fits, fits, "{}", c.strategy);
        if !c.fits {
            // oom placeholders are deterministic tombstones, never ranked
            assert!(!c.reachable && c.pruned, "{}", c.strategy);
            assert_eq!(c.throughput, 0.0, "{}", c.strategy);
        }
        priced += 1;
    }
    assert!(priced > 0, "memory stage priced nothing");
    let best = report.best().expect("something fits under 3 GB");
    assert!(best.fits && best.peak_bytes <= 3_000_000_000);
}
