//! Multi-connection saturation tests for the what-if daemon (ISSUE 6):
//! per-connection response ordering, per-connection byte-identity across
//! worker counts, prompt control ops while a neighbour sweeps, and
//! structured load-shedding when the bounded admission queue fills.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use distsim::config::Json;
use distsim::service::{serve_tcp, ServeOpts, ServeSummary};

fn parse(line: &str) -> Json {
    Json::parse(line).unwrap_or_else(|e| panic!("unparseable response '{line}': {e}"))
}

fn small_sweep(id: &str, global_batch: usize) -> String {
    format!(
        r#"{{"id":"{id}","op":"sweep","model":"bert-large","cluster":{{"preset":"a40","nodes":1,"gpus_per_node":4}},"sweep":{{"global_batch":{global_batch},"profile_iters":1}}}}"#
    )
}

fn response_id(j: &Json) -> String {
    j.get("id")
        .and_then(Json::as_str)
        .unwrap_or_else(|| panic!("no id in {j}"))
        .to_string()
}

/// Spawn a daemon, run `clients` request scripts against it concurrently
/// (one TCP connection each), and return each client's raw response lines
/// keyed by client tag.
fn run_fleet(
    opts: &ServeOpts,
    clients: Vec<(String, Vec<String>, usize)>,
) -> (BTreeMap<String, Vec<String>>, ServeSummary) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let daemon = std::thread::spawn({
        let opts = opts.clone();
        move || serve_tcp(listener, &opts).expect("serve_tcp")
    });

    let mut handles = Vec::new();
    for (tag, requests, expect) in clients {
        handles.push(std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).expect("connect");
            for line in &requests {
                writeln!(stream, "{line}").expect("send");
            }
            stream.flush().expect("flush");
            let reader = BufReader::new(stream.try_clone().expect("clone"));
            let lines: Vec<String> = reader
                .lines()
                .take(expect)
                .map(|l| l.expect("read response"))
                .collect();
            assert_eq!(lines.len(), expect, "client {tag} got a short stream");
            (tag, lines)
        }));
    }
    let mut by_tag = BTreeMap::new();
    for h in handles {
        let (tag, lines) = h.join().expect("client thread");
        by_tag.insert(tag, lines);
    }

    // all clients done: one control connection shuts the daemon down
    let mut ctl = TcpStream::connect(addr).expect("connect ctl");
    writeln!(ctl, r#"{{"id":"ctl","op":"shutdown"}}"#).expect("send shutdown");
    ctl.flush().expect("flush ctl");
    let summary = daemon.join().expect("daemon thread");
    (by_tag, summary)
}

/// The tentpole contract end to end at scale: ~100 concurrent connections,
/// each receiving its responses in its *own* admission order, with every
/// connection's stream byte-identical between 1 worker and 4 workers —
/// i.e. independent of scheduling, worker races and cross-connection
/// interleaving.
#[test]
fn per_connection_streams_are_ordered_and_byte_identical_across_worker_counts() {
    const CONNS: usize = 96;
    let clients = || -> Vec<(String, Vec<String>, usize)> {
        (0..CONNS)
            .map(|i| {
                let gb = if i % 2 == 0 { 4 } else { 8 };
                let tag = format!("c{i}");
                let requests = vec![
                    format!(r#"{{"id":"{tag}-p0","op":"ping"}}"#),
                    small_sweep(&format!("{tag}-s0"), gb),
                    small_sweep(&format!("{tag}-s1"), gb),
                    format!(r#"{{"id":"{tag}-p1","op":"ping"}}"#),
                ];
                (tag, requests, 4)
            })
            .collect()
    };

    let (one, s1) = run_fleet(
        &ServeOpts {
            workers: 1,
            ..ServeOpts::default()
        },
        clients(),
    );
    assert_eq!(s1.sweeps, 2 * CONNS);

    for (tag, lines) in &one {
        // per-connection admission order, regardless of the other 95
        // connections' traffic
        let ids: Vec<String> = lines.iter().map(|l| response_id(&parse(l))).collect();
        assert_eq!(
            ids,
            vec![
                format!("{tag}-p0"),
                format!("{tag}-s0"),
                format!("{tag}-s1"),
                format!("{tag}-p1")
            ],
            "connection {tag} saw out-of-order responses"
        );
        // per-connection as-if-serial cache accounting: the first sweep is
        // always cold *for this connection* (never silently warmed by a
        // neighbour), the identical repeat always a full hit
        let s0 = parse(&lines[1]);
        let cache0 = s0.get("result").unwrap().get("cache").unwrap();
        assert!(
            cache0.get("misses").and_then(Json::as_usize).unwrap() > 0,
            "{tag}: first sweep must be cold under per-connection scoping"
        );
        let s1 = parse(&lines[2]);
        let cache1 = s1.get("result").unwrap().get("cache").unwrap();
        assert_eq!(
            cache1.get("misses").and_then(Json::as_usize),
            Some(0),
            "{tag}: identical repeat on the same connection must hit"
        );
    }

    let (four, s4) = run_fleet(
        &ServeOpts {
            workers: 4,
            ..ServeOpts::default()
        },
        clients(),
    );
    assert_eq!(s4.sweeps, 2 * CONNS);
    assert_eq!(
        one, four,
        "some connection's stream changed between 1 and 4 workers"
    );
}

/// A ping on an idle connection is answered while another connection's
/// sweeps occupy the single worker — the cross-connection head-of-line
/// block this PR removes (the ping used to wait behind every earlier
/// admitted sweep).
#[test]
fn idle_connection_ping_is_answered_during_anothers_sweeps() {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let daemon = std::thread::spawn(move || {
        serve_tcp(
            listener,
            &ServeOpts {
                workers: 1,
                ..ServeOpts::default()
            },
        )
        .expect("serve_tcp")
    });

    // connection A: enough sweeps to keep the lone worker busy
    let mut a = TcpStream::connect(addr).expect("connect a");
    for i in 0..4 {
        writeln!(a, "{}", small_sweep(&format!("a{i}"), 8)).expect("send");
    }
    a.flush().expect("flush");
    let a_reader = std::thread::spawn(move || {
        let reader = BufReader::new(a.try_clone().expect("clone"));
        let lines: Vec<String> = reader.lines().take(4).map(|l| l.expect("read")).collect();
        (Instant::now(), lines)
    });

    // connection B pings while A's sweeps are in flight
    std::thread::sleep(Duration::from_millis(30));
    let mut b = TcpStream::connect(addr).expect("connect b");
    writeln!(b, r#"{{"id":"b","op":"ping"}}"#).expect("send ping");
    b.flush().expect("flush b");
    let mut pong = String::new();
    BufReader::new(b.try_clone().expect("clone"))
        .read_line(&mut pong)
        .expect("read pong");
    let pong_at = Instant::now();
    let j = parse(pong.trim());
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j}");
    assert_eq!(response_id(&j), "b");

    let (a_done_at, a_lines) = a_reader.join().expect("a reader");
    assert_eq!(a_lines.len(), 4);
    for (i, line) in a_lines.iter().enumerate() {
        assert_eq!(response_id(&parse(line)), format!("a{i}"));
    }
    assert!(
        pong_at < a_done_at,
        "B's ping waited for A's whole backlog (head-of-line block)"
    );

    writeln!(b, r#"{{"op":"shutdown"}}"#).expect("send shutdown");
    b.flush().expect("flush");
    daemon.join().expect("daemon");
}

/// Burst far past a tiny `--max-queue` from many connections at once:
/// every request is answered (ok sweep or structured `unavailable` shed —
/// never dropped, never unbounded growth), and the daemon stays healthy
/// afterwards.
#[test]
fn saturated_queue_sheds_cleanly_across_connections() {
    const CONNS: usize = 32;
    let opts = ServeOpts {
        workers: 1,
        max_queue: 2,
        ..ServeOpts::default()
    };
    let clients: Vec<(String, Vec<String>, usize)> = (0..CONNS)
        .map(|i| {
            let tag = format!("burst{i}");
            // distinct batch sizes keep every sweep cold (real profiling
            // work), so the lone worker cannot outrun the burst
            (tag.clone(), vec![small_sweep(&tag, 4 + 4 * (i % 8))], 1)
        })
        .collect();
    let (by_tag, summary) = run_fleet(&opts, clients);

    let mut oks = 0usize;
    let mut sheds = 0usize;
    for (tag, lines) in &by_tag {
        let j = parse(&lines[0]);
        assert_eq!(response_id(&j), *tag);
        if j.get("ok").and_then(Json::as_bool) == Some(true) {
            oks += 1;
        } else {
            let err = j.get("error").expect("error object");
            assert_eq!(
                err.get("kind").and_then(Json::as_str),
                Some("unavailable"),
                "{j}"
            );
            assert!(
                err.get("message")
                    .and_then(Json::as_str)
                    .unwrap()
                    .contains("queue is full"),
                "{j}"
            );
            sheds += 1;
        }
    }
    assert_eq!(oks + sheds, CONNS, "every burst request was answered");
    assert!(oks >= 1, "the head sweep always runs");
    assert!(
        sheds >= 1,
        "{CONNS} simultaneous sweeps vs --max-queue 2 must shed"
    );
    assert_eq!(summary.sweeps, oks);
    assert_eq!(summary.errors, sheds);
}
