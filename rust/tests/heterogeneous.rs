//! Integration tests for heterogeneous mixed-SKU clusters (ISSUE 4):
//! cache-key separation across device kinds, placement-map round-trips,
//! end-to-end engine/model agreement on mixed fleets, the sweep's
//! placement axis (bit-identity + attribution), and the acceptance
//! criterion — a mixed-SKU sweep demonstrably differs from the
//! homogeneous baseline.

use std::collections::HashSet;

use distsim::cluster::{ClusterSpec, DeviceSpec, Placement, PlacementPolicy};
use distsim::config::{Json, RunConfig};
use distsim::cost::CostModel;
use distsim::search::{fingerprint, SearchEngine, SweepConfig, SweepReport};
use distsim::strategy::Strategy;

fn mixed() -> ClusterSpec {
    ClusterSpec::mixed_a40_a10(2, 4)
}

fn homogeneous() -> ClusterSpec {
    ClusterSpec::a40_cluster(2, 4)
}

fn sweep_cfg(placement_axis: bool, threads: usize) -> SweepConfig {
    SweepConfig {
        global_batch: 8,
        profile_iters: 1,
        threads,
        placement_axis,
        ..SweepConfig::default()
    }
}

fn run_sweep(cluster: &ClusterSpec, cfg: SweepConfig) -> SweepReport {
    let model = distsim::model::zoo::bert_large();
    let cost = CostModel::default();
    SearchEngine::new(&model, cluster, &cost, cfg).sweep()
}

// -- acceptance: mixed-SKU sweeps differ from homogeneous ----------------

#[test]
fn mixed_sweep_differs_from_homogeneous_and_attributes_the_delta() {
    let homog = run_sweep(&homogeneous(), sweep_cfg(false, 1));
    let mixed = run_sweep(&mixed(), sweep_cfg(true, 1));

    // the axis actually enumerated placements
    for p in PlacementPolicy::AXIS {
        assert!(
            mixed.candidates.iter().any(|c| c.placement == p),
            "placement axis missing {p}"
        );
    }

    // every interleaved-placement winner is measurably worse than the
    // homogeneous baseline's: 8 ranks on 4xA40+4xA10 cannot match 8xA40
    let best_homog = homog.best().expect("homogeneous sweep has a winner");
    let best_interleaved = mixed
        .candidates
        .iter()
        .filter(|c| c.placement == PlacementPolicy::Interleaved && c.evaluated())
        .max_by(|a, b| a.throughput.total_cmp(&b.throughput))
        .expect("interleaved candidates evaluated");
    let differs_in_strategy = best_interleaved.strategy != best_homog.strategy;
    let rel = (best_homog.throughput - best_interleaved.throughput).abs()
        / best_homog.throughput;
    assert!(
        differs_in_strategy || rel > 0.02,
        "mixed interleaved best ({} @ {:.4} it/s) indistinguishable from \
         homogeneous best ({} @ {:.4} it/s)",
        best_interleaved.strategy,
        best_interleaved.throughput,
        best_homog.strategy,
        best_homog.throughput
    );

    // and the report attributes the placement axis's contribution
    let attr = mixed
        .placement_attribution()
        .expect("placement attribution on a placement-axis sweep");
    assert!(attr.placement_speedup >= 1.0, "{attr:?}");
    assert!(attr.strategy_speedup >= 1.0, "{attr:?}");
    assert!(
        PlacementPolicy::AXIS.contains(&attr.winning_placement),
        "{attr:?}"
    );

    // placement genuinely moves the needle for at least one strategy:
    // some candidate's fast-first and interleaved evaluations differ
    let moved = mixed.candidates.iter().any(|a| {
        a.placement == PlacementPolicy::FastFirst
            && a.evaluated()
            && mixed.candidates.iter().any(|b| {
                b.placement == PlacementPolicy::Interleaved
                    && b.strategy == a.strategy
                    && b.micro_batch_size == a.micro_batch_size
                    && b.schedule == a.schedule
                    && b.evaluated()
                    && (b.throughput - a.throughput).abs() / a.throughput > 1e-6
            })
    });
    assert!(moved, "no strategy's throughput depends on placement");
}

// -- cache-key separation across device kinds ----------------------------

#[test]
fn warm_homogeneous_snapshot_yields_no_hits_for_a_mixed_cluster() {
    let model = distsim::model::zoo::bert_large();
    let cost = CostModel::default();
    let book = distsim::cost::CostBook::uniform(cost.clone());

    // warm sweep on the homogeneous fleet; harvest its snapshot keys
    let homog = homogeneous();
    let homog_rep = SearchEngine::new(&model, &homog, &cost, sweep_cfg(false, 1)).sweep();
    let homog_keys: HashSet<String> =
        homog_rep.event_uses.iter().map(|u| u.key.clone()).collect();
    assert!(!homog_keys.is_empty());

    // fingerprints differ, so no registry/CLI path would ever apply the
    // homogeneous snapshot to the mixed fleet in the first place
    assert_ne!(
        fingerprint(&homogeneous(), &book, 0.0, 1, 7777),
        fingerprint(&mixed(), &book, 0.0, 1, 7777),
        "mixed and homogeneous fleets must have distinct cache identities"
    );

    // and even if it were force-shared as a prior, not one computation
    // event of the mixed sweep is served by it: mixed A40 events carry
    // the same kind but A10 ranks intern their own descriptors, and a
    // degenerate all-A10-via-kinds cluster shares nothing at all
    let mut all_a10 = homogeneous();
    all_a10.extra_kinds = vec![DeviceSpec::a10()];
    let n = all_a10.total_devices();
    all_a10.kind_of_device = vec![1; n];
    let rep = run_sweep(&all_a10, sweep_cfg(false, 1));
    let comp_uses: Vec<&str> = rep
        .event_uses
        .iter()
        .filter(|u| u.key.contains("\"type\":\"comp\""))
        .map(|u| u.key.as_str())
        .collect();
    assert!(!comp_uses.is_empty());
    for key in comp_uses {
        assert!(
            !homog_keys.contains(key),
            "A40 snapshot served an A10 computation event: {key}"
        );
        assert!(key.contains("\"kind\":\"A10\""), "{key}");
    }
}

#[test]
fn priming_a_service_with_homogeneous_sweeps_cannot_change_mixed_answers() {
    use distsim::service::{serve_ndjson, ServeOpts};
    use std::io::Cursor;

    let homog_req = r#"{"id":"h","op":"sweep","model":"bert-large","cluster":{"preset":"a40","nodes":1,"gpus_per_node":4},"sweep":{"global_batch":4,"profile_iters":1}}"#;
    let mixed_req = r#"{"id":"m","op":"sweep","model":"bert-large","cluster":{"preset":"a40-a10","nodes":2,"gpus_per_node":2},"sweep":{"global_batch":4,"profile_iters":1,"placement_axis":true}}"#;

    let run = |input: &str| -> Vec<String> {
        let mut out = Vec::new();
        serve_ndjson(
            Cursor::new(input.to_string()),
            &mut out,
            &ServeOpts {
                workers: 1,
                cache_dir: None,
                ..ServeOpts::default()
            },
        );
        String::from_utf8(out)
            .unwrap()
            .lines()
            .map(str::to_string)
            .collect()
    };

    let primed = run(&format!("{homog_req}\n{mixed_req}"));
    let fresh = run(mixed_req);
    assert_eq!(
        primed[1], fresh[0],
        "a warm homogeneous cache must contribute nothing (0% hits) to a \
         mixed-cluster sweep — byte-identical response either way"
    );
    // the mixed response still pays for its own profiling (cold cache)
    let j = Json::parse(&fresh[0]).unwrap();
    let cache = j.get("result").unwrap().get("cache").unwrap();
    assert!(cache.get("misses").and_then(Json::as_usize).unwrap() > 0);
    // and it reports a placement attribution
    assert!(j
        .get("result")
        .unwrap()
        .get("placement_attribution")
        .is_some());
}

// -- placement map JSON round-trip ---------------------------------------

#[test]
fn placement_map_round_trips_through_cluster_and_request_json() {
    // full-spec round-trip, all placement variants
    for placement in [
        Placement::Linear,
        Placement::FastFirst,
        Placement::Interleaved,
        Placement::Table(vec![3, 2, 1, 0, 7, 6, 5, 4]),
    ] {
        let c = mixed().with_placement(placement);
        let j = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(ClusterSpec::from_json(&j).unwrap(), c);
    }
    // preset + placement through the service's cluster parser
    let j = Json::parse(
        r#"{"preset":"a40-a10","nodes":2,"gpus_per_node":4,"placement":"interleaved"}"#,
    )
    .unwrap();
    let c = distsim::service::protocol::cluster_from_json(&j).unwrap();
    assert_eq!(c.placement, Placement::Interleaved);
    assert!(c.is_heterogeneous());
    // malformed tables are rejected, not silently accepted
    let bad = Json::parse(r#"{"preset":"a40-a10","nodes":2,"gpus_per_node":4,"placement":[0,0,0,0,0,0,0,0]}"#).unwrap();
    assert!(distsim::service::protocol::cluster_from_json(&bad).is_err());
}

// -- sweep bit-identity with the placement axis on -----------------------

#[test]
fn placement_axis_sweep_is_bit_identical_across_thread_counts() {
    let one = run_sweep(&mixed(), sweep_cfg(true, 1));
    for threads in [2, 4] {
        let many = run_sweep(&mixed(), sweep_cfg(true, threads));
        assert_eq!(one.candidates, many.candidates, "{threads} threads");
        assert_eq!(one.profile, many.profile, "{threads} threads");
        assert_eq!(one.cache, many.cache, "{threads} threads");
        assert_eq!(one.event_uses, many.event_uses, "{threads} threads");
    }
}

// -- ground-truth engine on mixed fleets ---------------------------------

#[test]
fn engine_brackets_mixed_fleet_between_homogeneous_bounds() {
    // "actually running" a strategy on the mixed fleet must be slower
    // than on all-A40 silicon and no slower than on all-A10 silicon
    let mut slow = homogeneous();
    slow.device = DeviceSpec::a10();
    let strategies = ["1M4P2D", "2M2P2D", "1M2P4D"];
    for s in strategies {
        let time_on = |cluster: &ClusterSpec| {
            let cfg = RunConfig::new(
                "bert-large",
                Strategy::parse(s).unwrap(),
                cluster.clone(),
            );
            distsim::engine::GroundTruth::prepare(&cfg)
                .unwrap()
                .mean_batch_time_us(3)
        };
        let tf = time_on(&homogeneous());
        let ts = time_on(&slow);
        let tm = time_on(&mixed());
        assert!(tm > tf * 1.01, "{s}: mixed {tm} !> fast {tf}");
        assert!(tm <= ts * 1.02, "{s}: mixed {tm} !<= slow {ts}");
    }
}

#[test]
fn distsim_tracks_the_engine_on_mixed_fleets() {
    // the paper's accuracy claim, extended to the mixed fleet: the
    // hierarchical model (max-over-kinds MP composition, per-replica
    // pipeline walks, barrier-gated gradient all-reduce) stays within a
    // loose band of the per-rank ground truth
    use distsim::metrics::batch_time_error_pct;
    for (s, placement) in [
        ("1M4P2D", Placement::Linear),
        ("2M2P2D", Placement::Linear),
        ("2M4P1D", Placement::Linear),
        // scattered placement: DP replicas get different SKU profiles and
        // different inter-stage link classes — the per-replica walk must
        // still track the per-rank engine
        ("1M4P2D", Placement::Interleaved),
        ("1M2P4D", Placement::FastFirst),
    ] {
        let cluster = mixed().with_placement(placement.clone());
        let mut cfg = RunConfig::new("bert-large", Strategy::parse(s).unwrap(), cluster);
        cfg.profile_iters = 30;
        let run = distsim::exp::eval_cfg(&cfg).unwrap();
        let actual = run.gt.run_iteration(0);
        let err = batch_time_error_pct(&run.predicted, &actual);
        assert!(
            err < 8.0,
            "{s} under {placement:?}: mixed-fleet batch-time error {err:.2}%"
        );
    }
}

#[test]
fn distsim_tracks_the_engine_under_lane_asymmetric_tables() {
    // ISSUE 5 satellite: MP-AR and grad-AR link classes are computed
    // exactly per group. This hand-crafted table breaks the lane
    // symmetry the named placements guarantee: MP pair (r0,r1) sits
    // intra-node on A40s, pairs (r2,r3)/(r4,r5) straddle nodes (inter
    // all-reduces, mixed SKUs), and the grad-AR groups (r0,r4) vs
    // (r1,r5) resolve to different classes. The representative-group
    // approximation this replaced mispriced exactly these lanes.
    use distsim::metrics::batch_time_error_pct;
    let table = vec![0, 1, 2, 4, 3, 5, 6, 7];
    let cluster = mixed().with_placement(Placement::Table(table));
    let mut cfg = RunConfig::new("bert-large", Strategy::parse("2M2P2D").unwrap(), cluster);
    cfg.profile_iters = 30;
    let run = distsim::exp::eval_cfg(&cfg).unwrap();
    let actual = run.gt.run_iteration(0);
    let err = batch_time_error_pct(&run.predicted, &actual);
    assert!(
        err < 8.0,
        "2M2P2D under a lane-asymmetric table: batch-time error {err:.2}%"
    );
}

#[test]
fn fast_first_placement_beats_interleaved_for_pipelines() {
    // placement search motivation: packing the fast SKUs into the early
    // ranks (= pipeline stages, Megatron order) beats scattering them
    let cfg = SweepConfig {
        global_batch: 8,
        profile_iters: 1,
        threads: 1,
        placement_axis: true,
        ..SweepConfig::default()
    };
    let rep = run_sweep(&mixed(), cfg);
    let best_of = |p: PlacementPolicy| {
        rep.candidates
            .iter()
            .filter(|c| c.placement == p && c.evaluated())
            .map(|c| c.throughput)
            .fold(f64::NEG_INFINITY, f64::max)
    };
    let ff = best_of(PlacementPolicy::FastFirst);
    let il = best_of(PlacementPolicy::Interleaved);
    assert!(
        ff >= il,
        "fast-first best ({ff}) should not lose to interleaved ({il})"
    );
}
