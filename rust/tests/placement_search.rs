//! Integration tests for the staged candidate pipeline (ISSUE 5):
//! thread-count bit-identity with adaptive epoch pruning, pruning
//! soundness against exhaustive sweeps (the optimizer never discards the
//! true optimum on a small fleet), table canonicalization properties, the
//! pruning-accounting invariants, and the headline acceptance — the
//! placement optimizer beating the three named placement policies on a
//! mixed-SKU fleet.

use distsim::cluster::{ClusterSpec, PlacementPolicy};
use distsim::cost::CostModel;
use distsim::model::zoo;
use distsim::search::{SearchEngine, SweepCandidate, SweepConfig, SweepReport};
use distsim::testutil;

fn mixed() -> ClusterSpec {
    ClusterSpec::mixed_a40_a10(2, 4)
}

fn run(model: &distsim::model::ModelSpec, cluster: &ClusterSpec, cfg: SweepConfig) -> SweepReport {
    SearchEngine::new(model, cluster, &CostModel::default(), cfg).sweep()
}

fn staged_cfg(threads: usize) -> SweepConfig {
    SweepConfig {
        global_batch: 8,
        profile_iters: 1,
        threads,
        placement_axis: true,
        placement_opt: true,
        prune: true,
        prune_epochs: 3,
        ..SweepConfig::default()
    }
}

// -- thread-count bit-identity with adaptive epochs -----------------------

#[test]
fn adaptive_epoch_sweep_is_bit_identical_across_thread_counts_homogeneous() {
    let model = zoo::bert_ex_large();
    let cluster = ClusterSpec::a10_cluster(4, 4);
    let cfg = |threads| SweepConfig {
        threads,
        prune: true,
        prune_epochs: 4,
        ..SweepConfig::default()
    };
    let one = run(&model, &cluster, cfg(1));
    for threads in [2, 8] {
        let many = run(&model, &cluster, cfg(threads));
        assert_eq!(one.candidates, many.candidates, "{threads} threads");
        assert_eq!(one.profile, many.profile, "{threads} threads");
        assert_eq!(one.cache, many.cache, "{threads} threads");
        assert_eq!(one.pruning, many.pruning, "{threads} threads");
    }
}

#[test]
fn adaptive_epoch_sweep_is_bit_identical_across_thread_counts_mixed() {
    let model = zoo::bert_large();
    let one = run(&model, &mixed(), staged_cfg(1));
    for threads in [2, 4] {
        let many = run(&model, &mixed(), staged_cfg(threads));
        assert_eq!(one.candidates, many.candidates, "{threads} threads");
        assert_eq!(one.profile, many.profile, "{threads} threads");
        assert_eq!(one.cache, many.cache, "{threads} threads");
        assert_eq!(one.event_uses, many.event_uses, "{threads} threads");
        assert_eq!(one.tables, many.tables, "{threads} threads");
        assert_eq!(one.pruning, many.pruning, "{threads} threads");
    }
}

// -- pruning soundness: the optimizer never discards the true optimum ----

fn key(c: &SweepCandidate) -> (String, &'static str, &'static str, usize, u32) {
    (
        c.strategy.notation(),
        c.schedule.name(),
        c.placement.name(),
        c.micro_batch_size,
        c.table,
    )
}

#[test]
fn pruned_optimizer_sweep_finds_the_exhaustive_optimum_on_a_small_fleet() {
    // <= 8 ranks: the symmetry-reduced table space is enumerated
    // completely, so the exhaustive (unpruned) sweep's winner is the true
    // optimum over every canonical placement; the pruned sweep must find
    // the bit-identical one
    let model = zoo::bert_large();
    let exhaustive = run(
        &model,
        &mixed(),
        SweepConfig {
            prune: false,
            ..staged_cfg(4)
        },
    );
    let pruned = run(&model, &mixed(), staged_cfg(4));
    assert!(
        pruned.pruned_count() > 0,
        "hundreds of table candidates must contain provably-losing ones"
    );
    let t = exhaustive.best().expect("exhaustive winner");
    let p = pruned.best().expect("pruned winner");
    assert_eq!(key(t), key(p), "pruning discarded the true optimum");
    assert_eq!(t.throughput, p.throughput);
    // pruned table candidates are never the argmax either
    for c in pruned.candidates.iter().filter(|c| c.pruned) {
        assert_ne!(key(c), key(t));
    }
}

// -- canonicalization / symmetry-reduction properties ---------------------

#[test]
fn prop_canonicalization_is_idempotent_class_preserving_and_injective() {
    testutil::check("table-canonicalization", 200, |rng| {
        let cluster = if rng.below(2) == 0 {
            ClusterSpec::mixed_a40_a10(2, 4)
        } else {
            ClusterSpec::mixed_a40_a10(3, 2)
        };
        let n = cluster.total_devices();
        // random permutation via Fisher-Yates on the rng
        let mut table: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            table.swap(i, j);
        }
        let canon = cluster.canonicalize_table(&table);
        // permutation
        let mut sorted = canon.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n).collect::<Vec<_>>());
        // class-preserving: every rank keeps its (node, kind)
        for r in 0..n {
            assert_eq!(
                cluster.device_class(table[r]),
                cluster.device_class(canon[r]),
                "rank {r} of {table:?}"
            );
        }
        // idempotent
        assert_eq!(cluster.canonicalize_table(&canon), canon);
        // canonical equality iff the rank->class maps agree: swapping two
        // same-class devices canonicalizes identically, swapping two
        // different-class devices does not
        let mut same = table.clone();
        let partner = (0..n).find(|&d| {
            d != table[0] && cluster.device_class(d) == cluster.device_class(table[0])
        });
        if let Some(partner) = partner {
            let pos = same.iter().position(|&d| d == partner).unwrap();
            same.swap(0, pos);
            assert_eq!(cluster.canonicalize_table(&same), canon);
        }
        let mut diff = table.clone();
        let other = (0..n)
            .find(|&d| cluster.device_class(d) != cluster.device_class(table[0]))
            .expect("mixed fleets have >= 2 classes");
        let pos = diff.iter().position(|&d| d == other).unwrap();
        diff.swap(0, pos);
        assert_ne!(cluster.canonicalize_table(&diff), canon);
    });
}

// -- pruning accounting ---------------------------------------------------

#[test]
fn pruning_accounting_is_consistent_and_surfaced() {
    let model = zoo::bert_large();
    let rep = run(&model, &mixed(), staged_cfg(2));
    let s = rep.pruning;
    assert_eq!(s.generated, rep.candidates.len());
    assert_eq!(s.bound_pruned + s.epoch_repruned, rep.pruned_count());
    assert_eq!(s.evaluated, s.generated - rep.pruned_count());
    assert!(s.bound_pruned > 0, "the table space must contain losers");
    assert!(
        s.gpu_seconds_avoided >= 0.0 && s.gpu_seconds_avoided.is_finite(),
        "{s:?}"
    );
    // an unpruned sweep reports a zeroed block (but the generated count)
    let flat = run(
        &model,
        &mixed(),
        SweepConfig {
            prune: false,
            placement_opt: false,
            ..staged_cfg(1)
        },
    );
    assert_eq!(flat.pruning.bound_pruned, 0);
    assert_eq!(flat.pruning.epoch_repruned, 0);
    assert_eq!(flat.pruning.gpu_seconds_avoided, 0.0);
    assert_eq!(flat.pruning.evaluated, flat.candidates.len());
}

#[test]
fn budgeted_staged_sweep_is_a_prefix_of_the_full_space() {
    let model = zoo::bert_large();
    let cluster = mixed();
    let cost = CostModel::default();
    let full = SearchEngine::new(&model, &cluster, &cost, staged_cfg(1)).specs();
    let capped = SearchEngine::new(
        &model,
        &cluster,
        &cost,
        SweepConfig {
            max_candidates: 7,
            ..staged_cfg(1)
        },
    )
    .specs();
    assert_eq!(capped.len(), 7);
    assert_eq!(capped[..], full[..7]);
}

// -- the acceptance criterion: optimizer beats the named policies ---------

#[test]
fn placement_optimizer_beats_all_three_named_policies_on_a_mixed_fleet() {
    // 2x4 mixed fleet (node 0 = 4xA40, node 1 = 4xA10), exhaustive table
    // regime. The named placements are structurally constrained: linear /
    // fast-first give whole replicas to the slow node (the DP-barrier
    // gradient all-reduce then waits for an all-A10 replica), and
    // interleaved scatters MP/stage neighbours across nodes. A canonical
    // table that balances SKUs per replica and keeps heavy stages on fast
    // silicon exists in the enumerated space and must win.
    let model = zoo::bert_large();
    let rep = run(
        &model,
        &mixed(),
        SweepConfig {
            prune: false, // exact: evaluate the whole space
            ..staged_cfg(4)
        },
    );
    let best_of = |pred: &dyn Fn(&SweepCandidate) -> bool| {
        rep.candidates
            .iter()
            .filter(|c| c.evaluated() && pred(c))
            .map(|c| c.throughput)
            .fold(0.0f64, f64::max)
    };
    let named = best_of(&|c| c.placement != PlacementPolicy::Optimized);
    let optimized = best_of(&|c| c.placement == PlacementPolicy::Optimized);
    assert!(named > 0.0 && optimized > 0.0);
    assert!(
        optimized >= named,
        "optimizer best ({optimized}) lost to the named policies ({named})"
    );

    // per-strategy strict win where the structure guarantees one: a
    // pipelined dp>=2 strategy — every named placement either starves a
    // replica (all-A10) or pays scattered links, while a balanced table
    // with the head stage on A40 silicon does neither
    let s = distsim::strategy::Strategy::new(1, 4, 2);
    let named_s = best_of(&|c| c.strategy == s && c.placement != PlacementPolicy::Optimized);
    let opt_s = best_of(&|c| c.strategy == s && c.placement == PlacementPolicy::Optimized);
    assert!(
        opt_s > named_s * 1.0000001,
        "1M4P2D: optimizer ({opt_s}) must strictly beat the named policies ({named_s})"
    );

    // the winner is reportable: attribution exists, and when the overall
    // winner is an optimized table, the report can name it
    assert!(rep.placement_attribution().is_some());
    if rep.best().unwrap().placement == PlacementPolicy::Optimized {
        let t = rep.winning_table().expect("winning table exposed");
        let mut sorted = t.to_vec();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<_>>());
    }
}
