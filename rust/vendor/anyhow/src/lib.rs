//! Offline stand-in for the `anyhow` crate.
//!
//! The environment this repo builds in has no crates.io registry, so the
//! workspace carries the slice of `anyhow`'s surface DistSim actually
//! uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`]
//! macros, and the [`Context`] extension trait for `Result` and `Option`.
//! Semantics match the real crate where it matters to callers:
//!
//! * `Display` shows the outermost message; `{:#}` joins the whole cause
//!   chain with `": "`.
//! * `Debug` (what `unwrap_err()` panics print) shows the chain as a
//!   `Caused by:` list.
//! * `?` converts any `std::error::Error + Send + Sync + 'static`.

use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error. `chain[0]` is the outermost (most recent)
/// context; the last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message (what `Context::context` does).
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        // flatten the source chain into our message chain
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

mod private {
    use super::Error;

    /// Sealed conversion used by [`super::Context`]: implemented for both
    /// std errors and [`Error`] itself (which deliberately does *not*
    /// implement `std::error::Error`, exactly like the real crate).
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: private::IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                concat!("condition failed: `", stringify!($cond), "`")
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().root_cause(), "file missing");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.chain().count(), 2);

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(7).context("fine").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        let e = anyhow!("plain {}", 1);
        assert_eq!(e.to_string(), "plain 1");
    }

    #[test]
    fn debug_prints_cause_list() {
        let e = Error::msg("root").context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
    }
}
