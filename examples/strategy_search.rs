//! The paper's §6 use-case: auto parallel-strategy search, served by the
//! parallel cache-aware sweep engine.
//!
//! ```bash
//! cargo run --release --offline --example strategy_search
//! ```
//!
//! Sweeps all 15 hybrid deployments of BERT-exLarge (48 layers) on
//! 4 nodes x 4 A10 GPUs at global batch 16, using DistSim as the
//! throughput oracle — profiled event costs shared across candidates
//! through the sweep's `ProfileCache`, candidates evaluated across worker
//! threads — then verifies the top/bottom picks on the ground-truth
//! engine (the paper's Table 2 protocol).

use distsim::cluster::ClusterSpec;
use distsim::cost::CostModel;
use distsim::model::zoo;
use distsim::search::{measure_actual_sweep, SearchEngine, SweepConfig};

fn main() -> anyhow::Result<()> {
    let model = zoo::bert_ex_large();
    let cluster = ClusterSpec::a10_cluster(4, 4);
    let cfg = SweepConfig {
        global_batch: 16,
        jitter_sigma: 0.02,
        profile_iters: 50,
        ..SweepConfig::default()
    };
    println!("== strategy search: {} on 16 x {} ==\n", model.name, cluster.device.name);
    let cost = CostModel::default();
    let engine = SearchEngine::new(&model, &cluster, &cost, cfg);
    let report = engine.sweep();

    let mut sorted = report.candidates.clone();
    sorted.sort_by(|a, b| b.throughput.total_cmp(&a.throughput));
    for c in &sorted {
        println!(
            "  {:10} {}",
            c.strategy.notation(),
            if c.evaluated() {
                format!("{:7.3} it/s", c.throughput)
            } else {
                "   unreachable (OOM)".to_string()
            }
        );
    }

    let best = report.best().expect("a reachable candidate");
    let worst = report.worst().expect("a reachable candidate");
    println!(
        "\nbest {} -> {:.2}x over worst {} (paper: 7.37x, winner pipeline-heavy, loser 16-way MP)",
        best.strategy,
        report.speedup().unwrap_or(f64::NAN),
        worst.strategy
    );
    println!(
        "search cost: {:.2} gpu-s profiling over {} unique events + {:.3} s wall on {} threads",
        report.profile.gpu_seconds,
        report.profile.events_profiled,
        report.timing.total_seconds,
        report.threads_used
    );
    println!(
        "profile cache: {} hits / {} misses ({:.0}% of lookups deduped)",
        report.cache.hits,
        report.cache.misses,
        report.cache.hit_rate() * 100.0
    );

    // Verify like the paper's Table 2: run best and worst "for real",
    // with the exact micro-batching the sweep simulated.
    println!("\nverifying on the ground-truth engine:");
    for c in [best, worst] {
        let actual = measure_actual_sweep("bert-exlarge", c, &cluster, 20)?;
        println!(
            "  {:10} DistSim {:.3} it/s   actual {:.3} it/s   ({:+.1}%)",
            c.strategy.notation(),
            c.throughput,
            actual,
            (c.throughput - actual) / actual * 100.0
        );
    }
    Ok(())
}
