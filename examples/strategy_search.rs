//! The paper's §6 use-case: auto parallel-strategy search.
//!
//! ```bash
//! cargo run --release --offline --example strategy_search
//! ```
//!
//! Grid-searches all 15 hybrid deployments of BERT-exLarge (48 layers) on
//! 4 nodes x 4 A10 GPUs at global batch 16, using DistSim as the
//! throughput oracle, then verifies the top/bottom picks on the
//! ground-truth engine (the paper's Table 2 protocol).

use distsim::cluster::ClusterSpec;
use distsim::cost::CostModel;
use distsim::model::zoo;
use distsim::search::{grid_search, measure_actual};

fn main() -> anyhow::Result<()> {
    let model = zoo::bert_ex_large();
    let cluster = ClusterSpec::a10_cluster(4, 4);
    let global_batch = 16;

    println!("== strategy search: {} on 16 x {} ==\n", model.name, cluster.device.name);
    let report = grid_search(&model, &cluster, &CostModel::default(), global_batch, 0.02, 50);

    let mut sorted = report.candidates.clone();
    sorted.sort_by(|a, b| b.throughput.partial_cmp(&a.throughput).unwrap());
    for c in &sorted {
        println!(
            "  {:10} {}",
            c.strategy.notation(),
            if c.reachable {
                format!("{:7.3} it/s", c.throughput)
            } else {
                "   unreachable (OOM)".to_string()
            }
        );
    }

    println!(
        "\nbest {} -> {:.2}x over worst {} (paper: 7.37x, winner pipeline-heavy, loser 16-way MP)",
        report.best().strategy,
        report.speedup(),
        report.worst().strategy
    );
    println!(
        "search cost: {:.2} gpu-s profiling + {:.3} s simulation",
        report.profile.gpu_seconds, report.simulate_seconds
    );

    // Verify like the paper's Table 2: run best and worst "for real".
    println!("\nverifying on the ground-truth engine:");
    for cand in [report.best(), report.worst()] {
        let actual = measure_actual("bert-exlarge", cand, &cluster, global_batch, 20)?;
        println!(
            "  {:10} DistSim {:.3} it/s   actual {:.3} it/s   ({:+.1}%)",
            cand.strategy.notation(),
            cand.throughput,
            actual,
            (cand.throughput - actual) / actual * 100.0
        );
    }
    Ok(())
}
