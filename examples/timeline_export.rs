//! Timeline inspection: export predicted and actual Chrome traces for one
//! configuration and report where the pipeline bubbles are — the paper's
//! §5.4 use-case (placing fault-tolerance work inside bubbles).
//!
//! ```bash
//! cargo run --release --offline --example timeline_export -- 2M4P1D
//! ```

use distsim::cluster::ClusterSpec;
use distsim::config::RunConfig;
use distsim::exp::eval_cfg;
use distsim::strategy::Strategy;
use distsim::timeline::analysis::{bubbles, utilization_summary};
use distsim::timeline::chrome::write_chrome_trace;
use distsim::util::fmt_us;

fn main() -> anyhow::Result<()> {
    let notation = std::env::args().nth(1).unwrap_or_else(|| "2M4P1D".into());
    let mut cfg = RunConfig::new(
        "bert-large",
        Strategy::parse(&notation)?,
        ClusterSpec::a40_cluster(4, 4),
    );
    cfg.micro_batches = 4;
    let run = eval_cfg(&cfg)?;

    let predicted = run.predicted.normalized();
    let actual = run.gt.run_iteration(0).normalized();
    write_chrome_trace(&predicted, "predicted_trace.json")?;
    write_chrome_trace(&actual, "actual_trace.json")?;
    println!("wrote predicted_trace.json and actual_trace.json (open in Perfetto)\n");

    let (lo, mean, hi) = utilization_summary(&predicted);
    println!("predicted utilization: min {lo:.2} mean {mean:.2} max {hi:.2}");

    // the biggest bubbles per device — candidates for fault-tolerance work
    let mut bs = bubbles(&predicted, 50.0);
    bs.sort_by(|a, b| b.dur().partial_cmp(&a.dur()).unwrap());
    println!("\nlargest pipeline bubbles (predicted):");
    for b in bs.iter().take(8) {
        println!(
            "  GPU {:2}  [{:>12} .. {:>12}]  {:>12}",
            b.device,
            fmt_us(b.start),
            fmt_us(b.end),
            fmt_us(b.dur())
        );
    }

    // did the prediction put bubbles where the real run has them?
    let actual_bubbles = bubbles(&actual, 50.0);
    println!(
        "\nbubble count: predicted {} vs actual {} (min 50 us)",
        bs.len(),
        actual_bubbles.len()
    );
    Ok(())
}
