//! End-to-end three-layer driver: prove L1 (Pallas kernels) → L2 (JAX
//! layer graphs) → L3 (Rust coordinator) compose on a real workload.
//!
//! ```bash
//! make artifacts   # once: python AOT-lowers the event graphs to HLO text
//! cargo run --release --offline --example runtime_calibration
//! ```
//!
//! Loads every AOT artifact through PJRT-CPU, executes it with real
//! numerics (this is the paper's CUPTI step, with the GPU swapped for the
//! CPU PJRT client), fits the cost model's scale to the measurements, and
//! then re-runs the headline Fig.-8-style accuracy experiment under the
//! calibrated cost model — demonstrating that profiling, modeling and
//! validation all run off measured compute. Records results in
//! EXPERIMENTS.md's end-to-end section.

use distsim::cluster::ClusterSpec;
use distsim::config::RunConfig;
use distsim::cost::CostModel;
use distsim::profile::calibrate::{fit_scale, measure_artifacts};
use distsim::runtime::artifacts_dir;
use distsim::strategy::Strategy;
use distsim::util::{fmt_us, rel_err_pct};

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    println!("== L1/L2 -> L3 bridge: measuring AOT artifacts in {} ==\n", dir.display());
    let mut cal = match measure_artifacts(&dir, 3) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("no artifacts ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };

    println!("{:<28} {:>12} {:>12}", "artifact", "latency", "GFLOP/s");
    for p in &cal.points {
        println!(
            "{:<28} {:>12} {:>12.2}",
            p.name,
            fmt_us(p.measured_us),
            p.flops as f64 / p.measured_us / 1e3
        );
    }
    println!("\nhost peak observed: {:.2} GFLOP/s", cal.host_gflops);

    // Fit the cost model's scale so a host-shaped device reproduces the
    // measured latencies, then use the calibrated model end-to-end.
    let base = CostModel::default();
    let host_tflops = cal.host_gflops / 1e3;
    fit_scale(&mut cal, &base, host_tflops);
    println!("fitted cost-model scale: {:.3}", cal.scale);
    cal.save(std::path::Path::new("calibration.json"))?;

    // Headline experiment under the calibrated model: DistSim vs ground
    // truth on BERT-Large 2M2P2D (both sides share the calibrated costs —
    // the accuracy claim is about *composition*, not absolute latency).
    let mut cost = CostModel::default();
    cost.scale = cal.scale;
    let cfg = RunConfig::new(
        "bert-large",
        Strategy::parse("2M2P2D")?,
        ClusterSpec::a40_cluster(4, 4),
    );
    let gt = distsim::engine::GroundTruth::prepare_with_cost(&cfg, cost.clone())?;
    let mut db = distsim::events::EventDb::new();
    distsim::engine::build_programs(&gt.part, &gt.sched, &cfg.cluster, &mut db);
    distsim::profile::profile_events(
        &mut db,
        &cfg.cluster,
        &distsim::cost::CostBook::uniform(cost.clone()),
        cfg.jitter_sigma,
        100,
        123,
    );
    let ds = distsim::distsim::DistSim::new(&gt.part, &gt.sched, &cfg.cluster);
    let pred = ds.predict_batch_time_us(&mut db);
    let actual = gt.mean_batch_time_us(20);
    println!(
        "\ncalibrated end-to-end: predicted {} vs actual {} -> error {:.2}%",
        fmt_us(pred),
        fmt_us(actual),
        rel_err_pct(pred, actual)
    );
    println!("(wrote calibration.json)");
    Ok(())
}
