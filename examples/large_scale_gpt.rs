//! Large-scale generalization (paper §5.5): model training a
//! 145-billion-parameter GPT on a 128-GPU A100 pod with Megatron-LM's
//! 8-way tensor MP x 16-stage pipeline, sweeping batch size — entirely
//! from events profiled on a 2-node slice.
//!
//! ```bash
//! cargo run --release --offline --example large_scale_gpt
//! ```

use distsim::cluster::ClusterSpec;
use distsim::config::RunConfig;
use distsim::exp::eval_cfg;
use distsim::strategy::Strategy;
use distsim::timeline::analysis;
use distsim::util::fmt_us;

fn main() -> anyhow::Result<()> {
    let cluster = ClusterSpec::a100_pod(16); // 16 nodes x 8 A100 = 128 GPUs
    let strategy = Strategy::parse("8M16P1D")?;
    let model = distsim::model::zoo::gpt_145b();
    println!(
        "== {} ({:.0} B params) on {} GPUs, {} ==\n",
        model.name,
        model.total_params() as f64 / 1e9,
        cluster.total_devices(),
        strategy
    );

    println!(
        "{:>6} {:>14} {:>12} {:>10} {:>8}",
        "batch", "batch time", "seq/s", "bubble", "util"
    );
    let mut base: Option<f64> = None;
    for batch in [1usize, 2, 4, 8, 16, 32, 64] {
        let mut cfg = RunConfig::new("gpt-145b", strategy, cluster.clone());
        cfg.micro_batch_size = 1;
        cfg.micro_batches = batch;
        cfg.profile_iters = 20;
        let run = eval_cfg(&cfg)?;
        let t = run.predicted.batch_time_us();
        let throughput = batch as f64 / (t / 1e6);
        let norm = throughput / *base.get_or_insert(throughput);
        let (_, util, _) = analysis::utilization_summary(&run.predicted);
        println!(
            "{batch:>6} {:>14} {throughput:>12.2} {:>9.1}% {util:>7.2} (x{norm:.2} vs batch 1)",
            fmt_us(t),
            analysis::bubble_ratio(&run.predicted) * 100.0,
        );
    }
    println!(
        "\nThe normalized scaling follows the bubble-amortization law 16b/(b+15),\n\
         which is what Megatron-LM reports for this configuration (paper Fig. 11)."
    );
    Ok(())
}
