//! The what-if sweep service, end to end and in-process:
//!
//! ```bash
//! cargo run --release --offline --example whatif_service
//! ```
//!
//! Simulates two daemon "sessions" against one snapshot directory. The
//! first session answers three what-if queries cold (profiling as it
//! goes, sharing measurements across requests through the fingerprint
//! cache registry) and persists its profile cache on shutdown; the second
//! session — a restarted daemon — answers the same headline query with a
//! 100% cache hit rate and zero GPU-seconds of profiling, returning the
//! byte-identical candidate ranking.

use std::io::Cursor;

use distsim::config::Json;
use distsim::service::{serve_ndjson, ServeOpts};

fn sweep_line(id: &str, model: &str, batch: usize) -> String {
    format!(
        r#"{{"id":"{id}","op":"sweep","model":"{model}","cluster":{{"preset":"a10","nodes":4,"gpus_per_node":4}},"sweep":{{"global_batch":{batch},"profile_iters":5}}}}"#
    )
}

fn show(tag: &str, line: &str) {
    let j = Json::parse(line).expect("service responses parse");
    let result = j.get("result").expect("ok response");
    let best = result.get("best").expect("a deployable candidate");
    let cache = result.get("cache").unwrap();
    println!(
        "  [{tag}] {}: best {} @ {:.3} it/s | speedup {:.2}x | cache {} hits / {} misses ({:.0}% hit rate, {:.2} gpu-s)",
        j.get("id").and_then(Json::as_str).unwrap_or("?"),
        best.get("strategy").and_then(Json::as_str).unwrap_or("?"),
        best.get("throughput").and_then(Json::as_f64).unwrap_or(0.0),
        result.get("speedup").and_then(Json::as_f64).unwrap_or(1.0),
        cache.get("hits").and_then(Json::as_usize).unwrap_or(0),
        cache.get("misses").and_then(Json::as_usize).unwrap_or(0),
        cache.get("hit_rate").and_then(Json::as_f64).unwrap_or(0.0) * 100.0,
        cache.get("gpu_seconds").and_then(Json::as_f64).unwrap_or(0.0),
    );
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("distsim_whatif_demo_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = ServeOpts {
        workers: 0, // all cores
        cache_dir: Some(dir.clone()),
        ..ServeOpts::default()
    };

    println!("== session 1: cold daemon, three what-if queries ==");
    let session1 = [
        sweep_line("q1-grid", "bert-exlarge", 16),
        sweep_line("q2-bigger-batch", "bert-exlarge", 32),
        sweep_line("q3-repeat", "bert-exlarge", 16),
        r#"{"op":"shutdown"}"#.to_string(),
    ]
    .join("\n");
    let mut out = Vec::new();
    let summary = serve_ndjson(Cursor::new(session1), &mut out, &opts);
    let text = String::from_utf8(out)?;
    for line in text.lines().take(3) {
        show("cold", line);
    }
    println!(
        "  served {} requests on shared caches; {} snapshot(s) persisted to {}",
        summary.requests,
        summary.snapshots_saved,
        dir.display()
    );

    println!("\n== session 2: restarted daemon, same headline query ==");
    let mut out2 = Vec::new();
    serve_ndjson(
        Cursor::new(sweep_line("q1-grid", "bert-exlarge", 16)),
        &mut out2,
        &opts,
    );
    let text2 = String::from_utf8(out2)?;
    show("warm", text2.lines().next().expect("one response"));

    // the restarted daemon must reproduce session 1's answer exactly
    let cold = Json::parse(text.lines().next().unwrap()).unwrap();
    let warm = Json::parse(text2.lines().next().unwrap()).unwrap();
    let candidates = |j: &Json| j.get("result").unwrap().get("candidates").unwrap().to_string();
    assert_eq!(
        candidates(&cold),
        candidates(&warm),
        "restart changed the ranking"
    );
    println!("\nrestart check: candidate rankings byte-identical across sessions");

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
