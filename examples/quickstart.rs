//! Quickstart: model one hybrid training configuration end to end.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Walks the full DistSim pipeline on BERT-Large with a 2-way-MP /
//! 2-way-PP / 2-way-DP strategy over 8 A40 GPUs:
//!   1. partition the model (Megatron-style),
//!   2. generate + dedup events,
//!   3. profile them on a 2-node slice,
//!   4. hierarchically compose the full-cluster timeline,
//!   5. compare against "actually running it" (the ground-truth engine).

use distsim::cluster::ClusterSpec;
use distsim::config::RunConfig;
use distsim::exp::eval_cfg;
use distsim::metrics;
use distsim::strategy::Strategy;
use distsim::timeline::analysis;
use distsim::util::{fmt_us, rel_err_pct, stats};

fn main() -> anyhow::Result<()> {
    // 1-2-3-4: config -> partition -> events -> profile -> predict
    let cfg = RunConfig::new(
        "bert-large",
        Strategy::parse("2M2P2D")?,
        ClusterSpec::a40_cluster(4, 4),
    );
    println!("== DistSim quickstart: {} / {} ==\n", cfg.model, cfg.strategy);

    let run = eval_cfg(&cfg)?;
    let predicted = run.predicted.batch_time_us();
    println!(
        "events: {} unique, profiled in {:.2} gpu-s on a 2-node slice",
        run.profile.events_profiled, run.profile.gpu_seconds
    );
    println!("predicted batch time: {}", fmt_us(predicted));

    // 5: the "real cluster" (ground-truth engine), 20 iterations
    let actual = run.gt.mean_batch_time_us(20);
    println!("actual batch time:    {}", fmt_us(actual));
    println!("batch-time error:     {:.2}%  (paper: < 4%)", rel_err_pct(predicted, actual));

    // per-GPU activity accuracy (paper Fig. 9)
    let errs = metrics::per_gpu_activity_error_pct(&run.predicted, &run.gt.run_iteration(0));
    println!(
        "per-GPU activity error: mean {:.2}%, max {:.2}%  (paper: < 5%)",
        stats::mean(&errs),
        stats::max(&errs)
    );

    // utilization / bubble analysis from the predicted timeline
    let (lo, mid, hi) = analysis::utilization_summary(&run.predicted);
    println!(
        "\npredicted utilization: min {lo:.2} mean {mid:.2} max {hi:.2}; bubble ratio {:.3}",
        analysis::bubble_ratio(&run.predicted)
    );

    // export a Chrome trace for Perfetto
    let trace = "quickstart_timeline.json";
    distsim::timeline::chrome::write_chrome_trace(&run.predicted, trace)?;
    println!("wrote {trace} — open in https://ui.perfetto.dev");
    Ok(())
}
